"""GPTQ baseline (Frantar et al., ICLR 2023) in JAX.

Column-wise optimal-brain-surgeon quantization with Cholesky-factored
Hessian and blocked error propagation. The paper uses GPTQ as a speed/
quality reference (Table 8); we implement it so the comparison is in-repo.

API: ``gptq_quantize(w, x_calib, bits, ...) -> (w_hat, info)`` matching
``core.baselines``.
"""
from __future__ import annotations

from functools import partial
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from .quantize import QuantSpec


def _hessian(x: jax.Array, n: int, damp_frac: float = 0.01) -> jax.Array:
    """H = 2 X Xᵀ over calibration tokens (x: (tokens, n)), dampened."""
    if x is None or x.shape[0] == 0:
        h = jnp.eye(n, dtype=jnp.float32)
    else:
        x32 = x.astype(jnp.float32)
        h = 2.0 * (x32.T @ x32) / x32.shape[0]
    damp = damp_frac * jnp.mean(jnp.diag(h)) + 1e-6
    return h + damp * jnp.eye(n, dtype=jnp.float32)


@partial(jax.jit, static_argnames=("spec",))
def _gptq_core(w: jax.Array, hinv_chol: jax.Array, spec: QuantSpec):
    """Sequential per-column quantization with error feedback.

    hinv_chol: upper-triangular Cholesky factor of H⁻¹ (as in the reference
    implementation). Group scales are frozen from the *original* weights
    (standard GPTQ behaviour with static groups).
    """
    m, n = w.shape
    g = spec.group_size
    # Static per-group qparams from original W.
    wg = w.reshape(m, n // g, g)
    if spec.symmetric:
        amax = jnp.max(jnp.abs(wg), axis=-1)
        scale_g = jnp.where(amax <= 0, 1.0, amax / spec.qmax)
        zp_g = jnp.zeros_like(scale_g)
    else:
        wmax = jnp.max(wg, axis=-1)
        wmin = jnp.min(wg, axis=-1)
        scale_g = (wmax - wmin) / spec.n_levels
        scale_g = jnp.where(scale_g <= 0, 1.0, scale_g)
        zp_g = jnp.round(-wmin / scale_g)

    def col_step(carry, j):
        w_work = carry  # (m, n) working copy with propagated error
        col = w_work[:, j]
        s = scale_g[:, j // g]
        z = zp_g[:, j // g]
        q = jnp.clip(jnp.round(col / s) + z, spec.qmin, spec.qmax)
        dq = (q - z) * s
        err = (col - dq) / hinv_chol[j, j]
        # propagate into remaining columns: w[:, k] -= err * Hinv_chol[j, k]
        row = hinv_chol[j, :]
        mask = (jnp.arange(n) > j).astype(jnp.float32)
        w_work = w_work - jnp.outer(err, row * mask)
        return w_work, dq

    _, dq_cols = jax.lax.scan(col_step, w.astype(jnp.float32), jnp.arange(n))
    return dq_cols.T  # (m, n)


def gptq_quantize(
    w: jax.Array,
    x_calib: Optional[jax.Array],
    bits: int,
    key=None,
    group_size: int = 128,
    symmetric: bool = False,
    damp_frac: float = 0.01,
) -> Tuple[jax.Array, dict]:
    spec = QuantSpec(bits, group_size, symmetric)
    n = w.shape[1]
    h = _hessian(x_calib, n, damp_frac)
    hinv = jnp.linalg.inv(h)
    # Upper Cholesky of H^-1 (reference impl: cholesky(Hinv, upper=True)).
    chol = jnp.linalg.cholesky(hinv, upper=True)
    # Normalize rows as the reference does (diagonal stays positive).
    what = _gptq_core(w.astype(jnp.float32), chol, spec)
    return what, dict(rank=0)
