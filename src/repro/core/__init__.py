"""FLRQ core: the paper's contribution as composable JAX modules."""
from .quantize import QuantSpec, pseudo_quantize, recon_error, awq_scale  # noqa: F401
from .r1_sketch import rank1_sketch, sketch_lowrank, sketch_lowrank_block  # noqa: F401
from .rsvd import rsvd, truncated_svd, lowrank_error  # noqa: F401
from .flr import FLRConfig, flexible_rank_select, flexible_rank_select_py  # noqa: F401
from .blc import blc, BLCResult  # noqa: F401
from .flrq import FLRQConfig, quantize_matrix, quantize_model, model_report  # noqa: F401
