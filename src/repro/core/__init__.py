"""FLRQ core: the paper's contribution as composable JAX modules."""
from .quantize import QuantSpec, pseudo_quantize, recon_error, awq_scale  # noqa: F401
from .r1_sketch import (  # noqa: F401
    rank1_sketch,
    resolve_backend,
    sketch_lowrank,
    sketch_lowrank_block,
    sketch_lowrank_block_masked,
)
from .rsvd import rsvd, truncated_svd, lowrank_error  # noqa: F401
from .flr import (  # noqa: F401
    FLRConfig,
    flexible_rank_select,
    flexible_rank_select_batched,
    flexible_rank_select_py,
)
from .blc import blc, blc_batched, BLCResult  # noqa: F401
from .flrq import (  # noqa: F401
    FLRQConfig,
    model_report,
    quantize_matrix,
    quantize_model,
    quantize_stack,
)
