"""R1-FLR: R1-Sketch-based Flexible Low-Rank Selection (paper Alg. 1 / 3).

Peels rank-1 components off a weight (or residual) matrix, tracking the
residual ``amax`` after every peel, and stops at the first rank where adding
another component no longer pays:

    p  = amax_0 / amax_r                 (precision gain factor)
    q  = (d + log2 p) / d                (effective-bit gain, Eq. 9)
    k  = 1 + d_fp * r * (m+n) / (d*m*n)  (storage growth, Eq. 9)
    stop if  k >= q        (gain no longer beats storage)
          or k >  1 + x    (memory budget, default x = 0.2)
          or slope < t     (amax curve flattened)

slope is the per-step relative amax decrease (amax_{r-1} - amax_r)/amax_0,
matching the paper's ``getSlope``.

Two implementations:
  * ``flexible_rank_select``      — jitted lax.while_loop into fixed-size
    buffers, returns (U, V, rank, stats). Used inside jit pipelines/BLC.
  * ``flexible_rank_select_py``   — python-driven loop (one jitted peel per
    step, stops immediately — zero wasted peels, the paper's "discrete"
    advantage). Used by the offline model quantizer and timing benchmarks.
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import NamedTuple, Tuple

import jax
import jax.numpy as jnp

from .r1_sketch import rank1_sketch


@dataclasses.dataclass(frozen=True)
class FLRConfig:
    bits: int = 4          # target quantization bit-width d
    x: float = 0.2         # max fractional model-size increase (paper default)
    t: float = 1e-4        # amax slope threshold
    it: int = 2            # power iterations per sketch (paper default)
    d_fp: int = 16         # storage precision of the low-rank factors
    max_rank: int = 128    # hard cap (truncated-SVD comparison uses 128/256)


class FLRResult(NamedTuple):
    u: jax.Array          # (m, max_rank) — columns beyond `rank` are zero
    v: jax.Array          # (max_rank, n)
    rank: jax.Array       # scalar int32, selected rank
    amax_trace: jax.Array # (max_rank + 1,) residual amax after each peel
    q: jax.Array          # final effective-bit gain
    k: jax.Array          # final storage growth


def _qk(amax0, amax, rank, m, n, cfg: FLRConfig):
    p = jnp.maximum(amax0 / jnp.maximum(amax, 1e-20), 1.0)
    q = (cfg.bits + jnp.log2(p)) / cfg.bits
    k = 1.0 + (cfg.d_fp * rank * (m + n)) / (cfg.bits * m * n)
    return q, k


@partial(jax.jit, static_argnames=("cfg",))
def flexible_rank_select(w: jax.Array, key: jax.Array, cfg: FLRConfig) -> FLRResult:
    """Fully-jitted R1-FLR. Buffers are sized ``cfg.max_rank``; the loop
    exits early via lax.while_loop so no wasted peels are *computed* (only
    allocated)."""
    m, n = w.shape
    max_r = min(cfg.max_rank, m, n)
    amax0 = jnp.max(jnp.abs(w)).astype(jnp.float32)
    keys = jax.random.split(key, max_r)

    u_buf = jnp.zeros((m, max_r), w.dtype)
    v_buf = jnp.zeros((max_r, n), w.dtype)
    trace = jnp.full((max_r + 1,), amax0, jnp.float32)

    def cond(state):
        i, _, _, _, _, done = state
        return (~done) & (i < max_r)

    def body(state):
        i, resid, u_buf, v_buf, trace, _ = state
        u1, v1 = rank1_sketch(resid, keys[i], it=cfg.it)
        resid_next = resid - jnp.outer(u1, v1).astype(resid.dtype)
        amax = jnp.max(jnp.abs(resid_next)).astype(jnp.float32)
        rank = (i + 1).astype(jnp.float32)
        q, k = _qk(amax0, amax, rank, m, n, cfg)
        slope = (trace[i] - amax) / jnp.maximum(amax0, 1e-20)
        stop = (k >= q) | (k > 1.0 + cfg.x) | (slope < cfg.t)
        # Accept the peel only if it pays.
        u_buf = jnp.where(stop, u_buf, u_buf.at[:, i].set(u1))
        v_buf = jnp.where(stop, v_buf, v_buf.at[i, :].set(v1))
        trace = trace.at[i + 1].set(jnp.where(stop, trace[i], amax))
        resid_next = jnp.where(stop, resid, resid_next)
        return (i + 1, resid_next, u_buf, v_buf, trace, stop)

    i, resid, u_buf, v_buf, trace, done = jax.lax.while_loop(
        cond, body, (jnp.int32(0), w, u_buf, v_buf, trace, jnp.bool_(False))
    )
    rank = jnp.where(done, i - 1, i).astype(jnp.int32)
    q, k = _qk(amax0, trace[rank], rank.astype(jnp.float32), m, n, cfg)
    return FLRResult(u_buf, v_buf, rank, trace, q, k)


def flexible_rank_select_py(
    w: jax.Array, key: jax.Array, cfg: FLRConfig
) -> Tuple[jax.Array, jax.Array, int, list]:
    """Python-driven R1-FLR (paper Alg. 1 verbatim): stops the moment the
    rule fires, returning exactly-(m, r)/(r, n) factors and the amax trace."""
    m, n = w.shape
    max_r = min(cfg.max_rank, m, n)
    resid = w
    amax0 = float(jnp.max(jnp.abs(w)))
    trace = [amax0]
    us, vs = [], []
    for i in range(max_r):
        key, sub = jax.random.split(key)
        u1, v1 = rank1_sketch(resid, sub, it=cfg.it)
        resid_next = resid - jnp.outer(u1, v1).astype(resid.dtype)
        amax = float(jnp.max(jnp.abs(resid_next)))
        rank = i + 1
        q, k = _qk(jnp.float32(amax0), jnp.float32(amax), rank, m, n, cfg)
        slope = (trace[-1] - amax) / max(amax0, 1e-20)
        if float(k) >= float(q) or float(k) > 1.0 + cfg.x or slope < cfg.t:
            break
        us.append(u1)
        vs.append(v1)
        trace.append(amax)
        resid = resid_next
    if not us:
        return (
            jnp.zeros((m, 0), w.dtype),
            jnp.zeros((0, n), w.dtype),
            0,
            trace,
        )
    return jnp.stack(us, axis=1), jnp.stack(vs, axis=0), len(us), trace
