"""R1-FLR: R1-Sketch-based Flexible Low-Rank Selection (paper Alg. 1 / 3).

Peels rank-1 components off a weight (or residual) matrix, tracking the
residual ``amax`` after every peel, and stops at the first rank where adding
another component no longer pays:

    p  = amax_0 / amax_r                 (precision gain factor)
    q  = (d + log2 p) / d                (effective-bit gain, Eq. 9)
    k  = 1 + d_fp * r * (m+n) / (d*m*n)  (storage growth, Eq. 9)
    stop if  k >= q        (gain no longer beats storage)
          or k >  1 + x    (memory budget, default x = 0.2)
          or slope < t     (amax curve flattened)

slope is the per-step relative amax decrease (amax_{r-1} - amax_r)/amax_0,
matching the paper's ``getSlope``.

Three implementations:
  * ``flexible_rank_select``      — jitted lax.while_loop into fixed-size
    buffers, returns (U, V, rank, stats). The stopping rule evaluates
    entirely on device (no host syncs), and the loop body is *batch-safe*:
    once a matrix stops, further (masked) iterations are no-ops, so the
    whole function can be ``vmap``-ed over a stack of layers.
  * ``flexible_rank_select_batched`` — exactly that vmap: one XLA launch
    selects ranks for all L layers of a stacked (L, m, n) tensor; the
    while_loop runs until every layer has stopped. This is the default
    engine of ``repro.quant.stacked``.
  * ``flexible_rank_select_py``   — python-driven loop (one jitted peel per
    step, stops immediately — zero wasted peels, the paper's "discrete"
    advantage — at the cost of a host sync per peel). Kept as the reference
    oracle and for the timing benchmarks.

The jitted variants consume the *same* PRNG key chain as the python one
(sequential ``split``), so all three produce identical peels and therefore
identical ranks on the same input.
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import NamedTuple, Tuple

import jax
import jax.numpy as jnp

from .r1_sketch import rank1_sketch


@dataclasses.dataclass(frozen=True)
class FLRConfig:
    bits: int = 4          # target quantization bit-width d
    x: float = 0.2         # max fractional model-size increase (paper default)
    t: float = 1e-4        # amax slope threshold
    it: int = 2            # power iterations per sketch (paper default)
    d_fp: int = 16         # storage precision of the low-rank factors
    max_rank: int = 128    # hard cap (truncated-SVD comparison uses 128/256)
    backend: str = "xla"   # sketch backend: "xla" | "pallas" | "auto"


class FLRResult(NamedTuple):
    u: jax.Array          # (m, max_rank) — columns beyond `rank` are zero
    v: jax.Array          # (max_rank, n)
    rank: jax.Array       # scalar int32, selected rank
    amax_trace: jax.Array # (max_rank + 1,) residual amax after each peel
    q: jax.Array          # final effective-bit gain
    k: jax.Array          # final storage growth


def _qk(amax0, amax, rank, m, n, cfg: FLRConfig):
    p = jnp.maximum(amax0 / jnp.maximum(amax, 1e-20), 1.0)
    q = (cfg.bits + jnp.log2(p)) / cfg.bits
    k = 1.0 + (cfg.d_fp * rank * (m + n)) / (cfg.bits * m * n)
    return q, k


def split_chain(key: jax.Array, n: int) -> Tuple[jax.Array, jax.Array]:
    """(subkeys (n, 2), advanced key) via the sequential split chain
    (``key, sub = split(key)`` per step). The ONE definition of the
    per-step PRNG discipline: the jitted FLR peels with it, the python
    oracle follows the same chain inline, and the stacked drivers use it
    per layer (as ``flrq.layer_key_chain``) — all of which must stay
    bit-identical for the engines to agree."""
    ks = []
    for _ in range(n):
        key, sub = jax.random.split(key)
        ks.append(sub)
    return jnp.stack(ks), key


@partial(jax.jit, static_argnames=("cfg",))
def flexible_rank_select(
    w: jax.Array, key: jax.Array, cfg: FLRConfig,
    active: jax.Array | None = None,
) -> FLRResult:
    """Fully-jitted R1-FLR. Buffers are sized ``cfg.max_rank``; the loop
    exits early via lax.while_loop so no wasted peels are *computed* (only
    allocated). The stopping decision never leaves the device.

    The body is masked-idempotent once ``done`` is set, which makes the
    function safe to ``vmap``: batching turns the while_loop condition into
    "any layer still running", and finished layers ride along unchanged.

    ``active``: optional traced bool — an inactive lane starts ``done`` and
    returns rank 0 with zero factors without peeling at all. This is the
    padding-lane mask of the mesh-sharded stack engine: a device whose
    local slice is all padding skips the while_loop entirely.
    """
    m, n = w.shape
    max_r = min(cfg.max_rank, m, n)
    amax0 = jnp.max(jnp.abs(w)).astype(jnp.float32)
    keys, _ = split_chain(key, max_r)
    inactive = (jnp.bool_(False) if active is None
                else ~jnp.asarray(active, jnp.bool_))

    u_buf = jnp.zeros((m, max_r), w.dtype)
    v_buf = jnp.zeros((max_r, n), w.dtype)
    trace = jnp.full((max_r + 1,), amax0, jnp.float32)

    def cond(state):
        i, _, _, _, _, _, done = state
        return (~done) & (i < max_r)

    def body(state):
        i, resid, u_buf, v_buf, trace, rank, done = state
        u1, v1 = rank1_sketch(resid, keys[i], it=cfg.it, backend=cfg.backend)
        resid_next = resid - jnp.outer(u1, v1).astype(resid.dtype)
        amax = jnp.max(jnp.abs(resid_next)).astype(jnp.float32)
        q, k = _qk(amax0, amax, (i + 1).astype(jnp.float32), m, n, cfg)
        slope = (trace[i] - amax) / jnp.maximum(amax0, 1e-20)
        stop = (k >= q) | (k > 1.0 + cfg.x) | (slope < cfg.t)
        # Accept the peel only if it pays — and never after `done` (a lane
        # that stopped in an earlier iteration must stay frozen under vmap,
        # trace included, so batched results are bit-identical to looping
        # the single-matrix call).
        take = (~done) & (~stop)
        u_buf = jnp.where(take, u_buf.at[:, i].set(u1), u_buf)
        v_buf = jnp.where(take, v_buf.at[i, :].set(v1), v_buf)
        trace = jnp.where(
            done, trace, trace.at[i + 1].set(jnp.where(stop, trace[i], amax)))
        resid = jnp.where(take, resid_next, resid)
        rank = jnp.where(take, i + 1, rank)
        return (i + 1, resid, u_buf, v_buf, trace, rank, done | stop)

    state = (jnp.int32(0), w, u_buf, v_buf, trace, jnp.int32(0),
             inactive)
    _, _, u_buf, v_buf, trace, rank, _ = jax.lax.while_loop(cond, body, state)
    q, k = _qk(amax0, trace[rank], rank.astype(jnp.float32), m, n, cfg)
    return FLRResult(u_buf, v_buf, rank, trace, q, k)


@partial(jax.jit, static_argnames=("cfg",))
def flexible_rank_select_batched(
    w: jax.Array, keys: jax.Array, cfg: FLRConfig,
    lane_mask: jax.Array | None = None,
) -> FLRResult:
    """R1-FLR for a whole (L, m, n) layer stack in ONE XLA launch.

    ``keys``: (L, 2) per-layer PRNG keys. Returns an FLRResult whose fields
    carry a leading L dim (u: (L, m, max_r), rank: (L,), ...). The vmapped
    while_loop iterates until the *slowest-stopping* layer is done; layers
    that stopped earlier are masked no-ops, so per-layer results are
    identical to calling ``flexible_rank_select`` in a loop — without the
    L × rank kernel dispatches and with zero host syncs.

    ``lane_mask``: optional (L,) bool — False lanes are padding (added by
    the mesh-sharded engine to round L up to the shard count) and resolve
    to rank 0 without any peel work.
    """
    if lane_mask is None:
        return jax.vmap(
            lambda wi, ki: flexible_rank_select(wi, ki, cfg))(w, keys)
    return jax.vmap(
        lambda wi, ki, ai: flexible_rank_select(wi, ki, cfg, active=ai)
    )(w, keys, jnp.asarray(lane_mask, jnp.bool_))


def flexible_rank_select_py(
    w: jax.Array, key: jax.Array, cfg: FLRConfig
) -> Tuple[jax.Array, jax.Array, int, list]:
    """Python-driven R1-FLR (paper Alg. 1 verbatim): stops the moment the
    rule fires, returning exactly-(m, r)/(r, n) factors and the amax trace.

    Each peel round-trips ``amax`` to the host (the ``float()`` calls) —
    that is the per-peel sync the batched engine exists to avoid; this
    implementation is kept as the reference oracle."""
    m, n = w.shape
    max_r = min(cfg.max_rank, m, n)
    resid = w
    amax0 = float(jnp.max(jnp.abs(w)))
    trace = [amax0]
    us, vs = [], []
    for i in range(max_r):
        key, sub = jax.random.split(key)
        u1, v1 = rank1_sketch(resid, sub, it=cfg.it, backend=cfg.backend)
        resid_next = resid - jnp.outer(u1, v1).astype(resid.dtype)
        amax = float(jnp.max(jnp.abs(resid_next)))
        rank = i + 1
        q, k = _qk(jnp.float32(amax0), jnp.float32(amax), rank, m, n, cfg)
        slope = (trace[-1] - amax) / max(amax0, 1e-20)
        if float(k) >= float(q) or float(k) > 1.0 + cfg.x or slope < cfg.t:
            break
        us.append(u1)
        vs.append(v1)
        trace.append(amax)
        resid = resid_next
    if not us:
        return (
            jnp.zeros((m, 0), w.dtype),
            jnp.zeros((0, n), w.dtype),
            0,
            trace,
        )
    return jnp.stack(us, axis=1), jnp.stack(vs, axis=0), len(us), trace
