"""FLRQ orchestration: per-matrix quantizer and whole-model driver
(paper Alg. 2: scaling → R1-FLR → clipping → BLC → pack).

The per-matrix pipeline:

  1. activation scaling  α = awq_scale(mean|X|)  (Eq. 10-11), W_s = W·diag(α),
     X_s = diag(α)⁻¹·X  (output-equivalent reparameterization);
  2. R1-FLR on W_s selects the rank r and initial (U, V);
  3. BLC alternates (re-sketch quant residual, re-clip, re-quant) keeping the
     best E = ||W_s X_s − (W_r + W_q) X_s||;
  4. the winner is packed into a QuantizedLinear (α⁻¹ folded into the
     runtime input scaling).

``quantize_model`` maps this over every 2-D parameter of a model pytree
that matches the quantization predicate (min size, not embeddings/norms),
producing a parallel pytree of QuantizedLinear + a stats report that the
benchmarks and EXPERIMENTS.md consume.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from .blc import blc as _run_blc
from .flr import FLRConfig, flexible_rank_select_py
from .quantize import (
    QuantSpec,
    awq_scale,
    channel_mean_abs,
    compute_qparams,
    pseudo_quantize,
    quantize_codes,
    recon_error,
    search_clip_ratio,
)
from ..quant import qtensor


@dataclasses.dataclass(frozen=True)
class FLRQConfig:
    bits: int = 4
    group_size: int = 128
    symmetric: bool = False
    x: float = 0.2               # memory budget (paper default)
    t: float = 1e-4              # amax slope threshold
    it: int = 2                  # sketch power iterations (paper default)
    max_rank: int = 128
    blc_epochs: int = 8          # paper: 1 suffices at 3/4-bit, ~20 at 2-bit
    use_scaling: bool = True
    use_blc: bool = True
    seed: int = 0
    store_dtype: Any = jnp.bfloat16

    def flr(self) -> FLRConfig:
        return FLRConfig(
            bits=self.bits, x=self.x, t=self.t, it=self.it, max_rank=self.max_rank
        )

    def spec(self) -> QuantSpec:
        return QuantSpec(self.bits, self.group_size, self.symmetric)

    def recommended_blc_epochs(self) -> int:
        # Paper Table 22: BLC converges in ~1 epoch at 3/4-bit, ~20 at 2-bit.
        return max(self.blc_epochs, 20) if self.bits <= 2 else self.blc_epochs


@dataclasses.dataclass
class LayerStats:
    name: str
    shape: Tuple[int, int]
    rank: int
    err_before: float      # RTN error at same bits (no low-rank, no scaling)
    err_after: float       # FLRQ error
    extra_bits: float
    clip: float
    seconds: float


def quantize_matrix(
    w: jax.Array,
    x_calib: Optional[jax.Array],
    cfg: FLRQConfig,
    key: jax.Array,
    name: str = "w",
) -> Tuple[qtensor.QuantizedLinear, LayerStats]:
    """Quantize one (m, n) matrix. ``x_calib``: (tokens, n) calibration
    activations feeding this matrix (None → unit scaling + Frobenius
    objectives).

    Robustness gate: activation scaling (Eq. 10-11) is heuristic — if the
    scaled pipeline ends up worse than the unscaled RTN floor, we redo the
    pipeline without scaling and keep the better result (a production
    quantizer must never regress below its own trivial baseline).
    """
    qt, st = _quantize_matrix_once(w, x_calib, cfg, key, name)
    if cfg.use_scaling and st.err_after > st.err_before:
        cfg2 = dataclasses.replace(cfg, use_scaling=False)
        qt2, st2 = _quantize_matrix_once(w, x_calib, cfg2, key, name)
        if st2.err_after < st.err_after:
            st2.seconds += st.seconds
            return qt2, st2
    return qt, st


def _quantize_matrix_once(
    w: jax.Array,
    x_calib: Optional[jax.Array],
    cfg: FLRQConfig,
    key: jax.Array,
    name: str = "w",
) -> Tuple[qtensor.QuantizedLinear, LayerStats]:
    t0 = time.perf_counter()
    m, n = w.shape
    spec = cfg.spec()
    w32 = w.astype(jnp.float32)

    if x_calib is None:
        x_calib = jnp.zeros((0, n), jnp.float32)
    xt = x_calib.astype(jnp.float32)

    # --- (1) activation scaling ------------------------------------------
    if cfg.use_scaling and xt.shape[0] > 0:
        alpha = awq_scale(channel_mean_abs(xt))
    else:
        alpha = jnp.ones((n,), jnp.float32)
    ws = w32 * alpha[None, :]
    xs = (xt / alpha[None, :]).T  # (n, tokens) column-batch in scaled space
    if xs.shape[1] == 0:
        xs_obj = jnp.eye(n, dtype=jnp.float32)  # Frobenius objective
    else:
        xs_obj = xs

    # --- baseline error (plain RTN, for the stats report) ----------------
    err_before = float(recon_error(w32, pseudo_quantize(w32, spec), xt.T if xt.shape[0] else None))

    # --- (2) flexible rank selection --------------------------------------
    key, k_flr, k_blc = jax.random.split(key, 3)
    u, v, rank, _trace = flexible_rank_select_py(ws, k_flr, cfg.flr())

    # --- (3)+(4) BLC (or single-shot clip+quant if disabled) --------------
    if cfg.use_blc:
        res = _run_blc(
            ws, xs_obj, k_blc, spec, rank,
            epochs=cfg.recommended_blc_epochs(), it=cfg.it,
        )
        u, v, clip = res.u, res.v, res.clip
        wq_deq = res.w_q
        err_after = float(res.err)
    else:
        resid = ws - (u @ v if rank else 0.0)
        clip = search_clip_ratio(resid, xs_obj, spec)
        wq_deq = pseudo_quantize(resid, spec, clip)
        err_after = float(recon_error(ws, wq_deq + (u @ v if rank else 0.0), xs_obj))
        clip = jnp.asarray(clip)

    # --- pack --------------------------------------------------------------
    resid_final = ws - (u @ v if rank else jnp.zeros_like(ws))
    scale, zp = compute_qparams(resid_final, spec, clip)
    codes = quantize_codes(resid_final, spec, scale, zp)
    if rank == 0:
        u = jnp.zeros((m, 0), jnp.float32)
        v = jnp.zeros((0, n), jnp.float32)
    qt = qtensor.from_parts(
        codes, scale, zp, u, v, spec,
        act_scale_inv=1.0 / alpha, store_dtype=cfg.store_dtype,
    )
    stats = LayerStats(
        name=name,
        shape=(m, n),
        rank=int(rank),
        err_before=err_before,
        err_after=err_after,
        extra_bits=qt.extra_avg_bits(),
        clip=float(clip),
        seconds=time.perf_counter() - t0,
    )
    return qt, stats


# ---------------------------------------------------------------------------
# Whole-model driver
# ---------------------------------------------------------------------------

def default_predicate(path: str, leaf) -> bool:
    """Quantize 2-D float matrices except embeddings / norms / tiny params."""
    if not hasattr(leaf, "ndim") or leaf.ndim != 2:
        return False
    if leaf.dtype not in (jnp.float32, jnp.bfloat16, jnp.float16):
        return False
    lname = path.lower()
    if any(s in lname for s in ("embed", "norm", "scale", "bias", "router")):
        return False
    m, n = leaf.shape
    return m >= 128 and n >= 128 and (n % 128 == 0)


def _flatten_with_paths(tree) -> Dict[str, Any]:
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        flat[jax.tree_util.keystr(path)] = leaf
    return flat


def quantize_model(
    params,
    calib_acts: Optional[Dict[str, jax.Array]],
    cfg: FLRQConfig,
    predicate: Callable[[str, Any], bool] = default_predicate,
    progress: Optional[Callable[[str, LayerStats], None]] = None,
):
    """Walk a parameter pytree; replace matching 2-D matrices with
    QuantizedLinear. ``calib_acts`` maps the same key-paths to (tokens, n)
    activation batches (missing entries → no calibration for that layer).

    Returns (quantized_tree, {path: LayerStats}).
    """
    key = jax.random.PRNGKey(cfg.seed)
    stats: Dict[str, LayerStats] = {}
    flat_paths = _flatten_with_paths(params)
    n_target = sum(1 for p, l in flat_paths.items() if predicate(p, l))
    keys = iter(jax.random.split(key, max(n_target, 1)))

    def visit(path, leaf):
        pstr = jax.tree_util.keystr(path)
        if not predicate(pstr, leaf):
            return leaf
        xc = None
        if calib_acts:
            xc = calib_acts.get(pstr)
        qt, st = quantize_matrix(leaf, xc, cfg, next(keys), name=pstr)
        stats[pstr] = st
        if progress:
            progress(pstr, st)
        return qt

    qtree = jax.tree_util.tree_map_with_path(visit, params)
    return qtree, stats


def model_report(stats: Dict[str, LayerStats]) -> Dict[str, float]:
    """Aggregate stats (paper Tables 3/9 style: avg rank, extra bits)."""
    if not stats:
        return dict(layers=0, avg_rank=0.0, avg_extra_bits=0.0,
                    mean_err_before=0.0, mean_err_after=0.0, seconds=0.0)
    n = len(stats)
    return dict(
        layers=n,
        avg_rank=sum(s.rank for s in stats.values()) / n,
        avg_extra_bits=sum(s.extra_bits for s in stats.values()) / n,
        mean_err_before=sum(s.err_before for s in stats.values()) / n,
        mean_err_after=sum(s.err_after for s in stats.values()) / n,
        seconds=sum(s.seconds for s in stats.values()),
    )
