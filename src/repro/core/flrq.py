"""FLRQ orchestration: per-matrix quantizer, batched per-stack quantizer,
and whole-model driver (paper Alg. 2: scaling → R1-FLR → clipping → BLC →
pack).

The per-matrix pipeline:

  1. activation scaling  α = awq_scale(mean|X|)  (Eq. 10-11), W_s = W·diag(α),
     X_s = diag(α)⁻¹·X  (output-equivalent reparameterization);
  2. R1-FLR on W_s selects the rank r and initial (U, V);
  3. BLC alternates (re-sketch quant residual, re-clip, re-quant) keeping the
     best E = ||W_s X_s − (W_r + W_q) X_s||;
  4. the winner is packed into a QuantizedLinear (α⁻¹ folded into the
     runtime input scaling).

``quantize_stack`` runs the same pipeline for all L layers of a stacked
(L, m, n) tensor as ONE jitted device program (vmapped R1-FLR with the
device-side stopping rule, batched BLC with per-layer rank masking, batched
clip search / qparams / bit-packing) — no per-peel host syncs, no per-layer
dispatch storms. This is the engine behind the default path of
``repro.quant.stacked.quantize_model_stacked``.

``quantize_model`` maps the per-matrix pipeline over every 2-D parameter of
a model pytree that matches the quantization predicate (min size, not
embeddings/norms), producing a parallel pytree of QuantizedLinear + a stats
report that the benchmarks and EXPERIMENTS.md consume.
"""
from __future__ import annotations

import dataclasses
import time
from functools import partial
from typing import Any, Callable, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from .blc import blc as _run_blc
from .blc import blc_batched as _run_blc_batched
from .flr import (
    FLRConfig,
    flexible_rank_select_batched,
    flexible_rank_select_py,
    split_chain,
)
from .quantize import (
    QuantSpec,
    awq_scale,
    channel_mean_abs,
    compute_qparams,
    pseudo_quantize,
    quantize_codes,
    recon_error,
    search_clip_ratio,
)
from ..quant import qtensor


@dataclasses.dataclass(frozen=True)
class FLRQConfig:
    bits: int = 4
    group_size: int = 128
    symmetric: bool = False
    x: float = 0.2               # memory budget (paper default)
    t: float = 1e-4              # amax slope threshold
    it: int = 2                  # sketch power iterations (paper default)
    max_rank: int = 128
    blc_epochs: int = 8          # paper: 1 suffices at 3/4-bit, ~20 at 2-bit
    use_scaling: bool = True
    use_blc: bool = True
    seed: int = 0
    store_dtype: Any = jnp.bfloat16
    backend: str = "xla"         # sketch backend: "xla" | "pallas" | "auto"
    clip_backend: str = "xla"    # clip-sweep backend: "xla"|"pallas"|"auto"

    def flr(self) -> FLRConfig:
        return FLRConfig(
            bits=self.bits, x=self.x, t=self.t, it=self.it,
            max_rank=self.max_rank, backend=self.backend,
        )

    def spec(self) -> QuantSpec:
        return QuantSpec(self.bits, self.group_size, self.symmetric)

    def recommended_blc_epochs(self) -> int:
        # Paper Table 22: BLC converges in ~1 epoch at 3/4-bit, ~20 at 2-bit.
        return max(self.blc_epochs, 20) if self.bits <= 2 else self.blc_epochs


@dataclasses.dataclass
class LayerStats:
    name: str
    shape: Tuple[int, int]
    rank: int
    err_before: float      # RTN error at same bits (no low-rank, no scaling)
    err_after: float       # FLRQ error
    extra_bits: float
    clip: float
    seconds: float


def quantize_matrix(
    w: jax.Array,
    x_calib: Optional[jax.Array],
    cfg: FLRQConfig,
    key: jax.Array,
    name: str = "w",
) -> Tuple[qtensor.QuantizedLinear, LayerStats]:
    """Quantize one (m, n) matrix. ``x_calib``: (tokens, n) calibration
    activations feeding this matrix (None → unit scaling + Frobenius
    objectives).

    Robustness gate: activation scaling (Eq. 10-11) is heuristic — if the
    scaled pipeline ends up worse than the unscaled RTN floor, we redo the
    pipeline without scaling and keep the better result (a production
    quantizer must never regress below its own trivial baseline).
    """
    qt, st = _quantize_matrix_once(w, x_calib, cfg, key, name)
    if cfg.use_scaling and st.err_after > st.err_before:
        cfg2 = dataclasses.replace(cfg, use_scaling=False)
        qt2, st2 = _quantize_matrix_once(w, x_calib, cfg2, key, name)
        if st2.err_after < st.err_after:
            st2.seconds += st.seconds
            return qt2, st2
    return qt, st


def _quantize_matrix_once(
    w: jax.Array,
    x_calib: Optional[jax.Array],
    cfg: FLRQConfig,
    key: jax.Array,
    name: str = "w",
) -> Tuple[qtensor.QuantizedLinear, LayerStats]:
    t0 = time.perf_counter()
    m, n = w.shape
    spec = cfg.spec()
    w32 = w.astype(jnp.float32)

    if x_calib is None:
        x_calib = jnp.zeros((0, n), jnp.float32)
    xt = x_calib.astype(jnp.float32)

    # --- (1) activation scaling ------------------------------------------
    if cfg.use_scaling and xt.shape[0] > 0:
        alpha = awq_scale(channel_mean_abs(xt))
    else:
        alpha = jnp.ones((n,), jnp.float32)
    ws = w32 * alpha[None, :]
    xs = (xt / alpha[None, :]).T  # (n, tokens) column-batch in scaled space
    if xs.shape[1] == 0:
        xs_obj = None  # Frobenius objective — scored directly, no eye(n)
    else:
        xs_obj = xs

    # --- baseline error (plain RTN, for the stats report) ----------------
    err_before = float(recon_error(w32, pseudo_quantize(w32, spec), xt.T if xt.shape[0] else None))

    # --- (2) flexible rank selection --------------------------------------
    key, k_flr, k_blc = jax.random.split(key, 3)
    u, v, rank, _trace = flexible_rank_select_py(ws, k_flr, cfg.flr())

    # --- (3)+(4) BLC (or single-shot clip+quant if disabled) --------------
    if cfg.use_blc:
        res = _run_blc(
            ws, xs_obj, k_blc, spec, rank,
            epochs=cfg.recommended_blc_epochs(), it=cfg.it,
            backend=cfg.backend, clip_backend=cfg.clip_backend,
        )
        u, v, clip = res.u, res.v, res.clip
        wq_deq = res.w_q
        err_after = float(res.err)
    else:
        resid = ws - (u @ v if rank else 0.0)
        clip = search_clip_ratio(resid, xs_obj, spec)
        wq_deq = pseudo_quantize(resid, spec, clip)
        err_after = float(recon_error(ws, wq_deq + (u @ v if rank else 0.0), xs_obj))
        clip = jnp.asarray(clip)

    # --- pack --------------------------------------------------------------
    resid_final = ws - (u @ v if rank else jnp.zeros_like(ws))
    scale, zp = compute_qparams(resid_final, spec, clip)
    codes = quantize_codes(resid_final, spec, scale, zp)
    if rank == 0:
        u = jnp.zeros((m, 0), jnp.float32)
        v = jnp.zeros((0, n), jnp.float32)
    qt = qtensor.from_parts(
        codes, scale, zp, u, v, spec,
        act_scale_inv=1.0 / alpha, store_dtype=cfg.store_dtype,
    )
    stats = LayerStats(
        name=name,
        shape=(m, n),
        rank=int(rank),
        err_before=err_before,
        err_after=err_after,
        extra_bits=qt.extra_avg_bits(),
        clip=float(clip),
        seconds=time.perf_counter() - t0,
    )
    return qt, stats


# ---------------------------------------------------------------------------
# Batched per-stack engine (all L layers of a stacked tensor in one program)
# ---------------------------------------------------------------------------

# Per-layer PRNG discipline = the per-peel discipline (one definition,
# flr.split_chain): quantize_stack consumes it and the stacked driver
# advances its cross-tensor chain with it, keeping both engines in sync.
layer_key_chain = split_chain


def _quantize_stack_impl(
    w_stack: jax.Array,   # (L, m, n) f32, quantizer orientation (m=out)
    xt: jax.Array,        # (tokens, n) shared — or (L, tokens, n) per-lane —
                          # calibration acts (tokens may be 0)
    keys: jax.Array,      # (L, 2) per-layer PRNG keys
    lane_mask: jax.Array, # (L,) bool; False lanes are shard padding
    x_index=None,         # (L,) int32 — xt is then a (U, tokens, n) stack of
                          # UNIQUE batches, gathered per lane device-side
    cfg: FLRQConfig = None,
    use_scaling: bool = False,
    has_calib: bool = False,
    return_resid: bool = False,
):
    """The whole FLRQ pipeline for a layer stack as ONE device program:
    batched scaling → vmapped R1-FLR (device-side stopping) → batched BLC
    (rank-masked blocked re-sketch) or batched clip search → batched
    qparams/codes/bit-packing. Returns a dict of L-leading arrays.

    This is the per-device body of the mesh-sharded engine: every step is
    local to the lanes it is given (the calibration batch is replicated),
    so ``shard_map``-ing it over the leading dim quantizes each shard
    independently with zero interconnect traffic until the final gather.

    ``xt`` with a leading lane dim carries a *per-layer* calibration batch —
    the same-shape stack fusion uses this to concatenate weight families
    that see different activations (Q/K/V vs O) into one launch. With
    ``x_index``, ``xt`` holds only the UNIQUE batches (one per fused group
    member) and each lane gathers its own inside the program — the host
    never materializes, ships, or shards the ~G·L× broadcast copy.
    """
    L, m, n = w_stack.shape
    spec = cfg.spec()
    w32 = w_stack.astype(jnp.float32)
    xt = xt.astype(jnp.float32)
    if x_index is not None:
        xt = xt[x_index]              # (L, tokens, n), device-side gather
    per_lane = xt.ndim == 3

    # --- (1) activation scaling --------------------------------------------
    if use_scaling and has_calib:
        if per_lane:
            alpha = jax.vmap(
                lambda x_l: awq_scale(channel_mean_abs(x_l)))(xt)  # (L, n)
        else:
            alpha = awq_scale(channel_mean_abs(xt))                # (n,)
    else:
        alpha = jnp.ones(((L, n) if per_lane else (n,)), jnp.float32)
    ws = w32 * (alpha[:, None, :] if per_lane else alpha[None, None, :])
    if has_calib:
        # scaled-space objective (n, tokens) — per-lane: (L, n, tokens)
        if per_lane:
            xs_obj = jnp.swapaxes(xt / alpha[:, None, :], -1, -2)
            x_err = jnp.swapaxes(xt, -1, -2)
        else:
            xs_obj = (xt / alpha[None, :]).T
            x_err = xt.T                      # unscaled-space error objective
    else:
        xs_obj = None  # Frobenius objective — scored directly, no eye(n)
        x_err = None
        per_lane = False
    x_axis = 0 if per_lane else None

    # --- baseline error (plain RTN per layer, for the stats report) --------
    if x_err is None:
        err_before = jax.vmap(
            lambda wl: recon_error(wl, pseudo_quantize(wl, spec), None))(w32)
    else:
        err_before = jax.vmap(
            lambda wl, xl: recon_error(wl, pseudo_quantize(wl, spec), xl),
            in_axes=(0, x_axis))(w32, x_err)

    # --- per-layer keys: same split discipline as quantize_matrix ----------
    k3 = jax.vmap(lambda k: jax.random.split(k, 3))(keys)  # (L, 3, 2)
    k_flr, k_blc = k3[:, 1], k3[:, 2]

    # --- (2) flexible rank selection: one launch for the whole stack -------
    flr = flexible_rank_select_batched(ws, k_flr, cfg.flr(),
                                       lane_mask=lane_mask)
    ranks = flr.rank                           # (L,) int32
    max_r = flr.u.shape[-1]                    # static buffer width

    # --- (3)+(4) BLC (or single-shot clip+quant if disabled) ---------------
    if cfg.use_blc:
        res = _run_blc_batched(
            ws, xs_obj, k_blc, spec, ranks, max_r,
            epochs=cfg.recommended_blc_epochs(), it=cfg.it,
            backend=cfg.backend, clip_backend=cfg.clip_backend,
        )
        u, v, clip, err_after = res.u, res.v, res.clip, res.err
    else:
        u, v = flr.u.astype(jnp.float32), flr.v.astype(jnp.float32)
        resid = ws - u @ v

        def one(resid_l, xs_l):
            c = search_clip_ratio(resid_l, xs_l, spec)
            return c, pseudo_quantize(resid_l, spec, c)

        if xs_obj is None:
            clip, wq = jax.vmap(lambda r_l: one(r_l, None))(resid)
            err_after = jax.vmap(
                lambda wl, wh: recon_error(wl, wh, None))(ws, wq + u @ v)
        else:
            clip, wq = jax.vmap(one, in_axes=(0, x_axis))(resid, xs_obj)
            err_after = jax.vmap(
                lambda wl, wh, xl: recon_error(wl, wh, xl),
                in_axes=(0, 0, x_axis))(ws, wq + u @ v, xs_obj)

    # --- pack ---------------------------------------------------------------
    resid_final = ws - u @ v
    scale, zp = jax.vmap(
        lambda r, c: compute_qparams(r, spec, c))(resid_final, clip)
    codes = jax.vmap(
        lambda r, s, z: quantize_codes(r, spec, s, z))(resid_final, scale, zp)
    packed = qtensor.pack_codes(codes, spec)
    out = dict(
        packed=packed, scale=scale, zp=zp, u=u, v=v,
        act_scale_inv=jnp.broadcast_to(1.0 / alpha, (L, n)),
        ranks=ranks, clip=clip,
        err_before=err_before, err_after=err_after,
    )
    if return_resid:
        # Same aval as w_stack: the donation target. When the caller donates
        # the weight stack, XLA writes this (otherwise temp-allocated)
        # residual into the donated buffer — peak drops by one full
        # (L, m, n) f32 stack. The driver discards it after the launch.
        out["resid"] = resid_final
    return out


_STACK_STATICS = ("cfg", "use_scaling", "has_calib", "return_resid")
_quantize_stack_jit = partial(jax.jit, static_argnames=_STACK_STATICS)(
    _quantize_stack_impl)
# Donating twin: consumes the w_stack buffer. Single-partition XLA binds a
# donation only to an output with the exact same aval, so the donating
# launch requests the residual output and aliases the stack into it.
_quantize_stack_jit_donate = partial(
    jax.jit, static_argnames=_STACK_STATICS,
    donate_argnames=("w_stack",))(_quantize_stack_impl)


def _quantize_stack_sharded_impl(
    w_stack: jax.Array,
    xt: jax.Array,
    keys: jax.Array,
    lane_mask: jax.Array,
    x_index=None,
    cfg: FLRQConfig = None,
    use_scaling: bool = False,
    has_calib: bool = False,
    mesh=None,
    axis: str = None,
):
    """Mesh-sharded batched engine: ``shard_map`` of the per-device pipeline
    over ``mesh`` axis ``axis``. Each device quantizes its slice of the
    (L, m, n) stack — rank selection, masked block sketch, clip search and
    bit-packing all stay device-local; the calibration batch is replicated
    (per-lane calibration shards with its lanes; an ``x_index`` gather
    replicates only the small unique-batch stack and shards the index, so
    each device gathers just its own lanes' objectives) and only the final
    QTensor gather crosses the interconnect.

    ``check_rep=False``: the body contains lax.while_loop (R1-FLR's
    device-side stopping rule and the rank-masked block sketch), which has
    no shard_map replication rule — every input is either explicitly
    sharded on the leading dim or replicated, so the check is vacuous here.
    """
    from jax.experimental.shard_map import shard_map
    from jax.sharding import PartitionSpec as P

    body = partial(_quantize_stack_impl, cfg=cfg, use_scaling=use_scaling,
                   has_calib=has_calib)
    if x_index is None:
        xt_spec = P(axis) if xt.ndim == 3 else P()
        fn = shard_map(
            lambda w, x, k, lm: body(w, x, k, lm),
            mesh=mesh,
            in_specs=(P(axis), xt_spec, P(axis), P(axis)),
            out_specs=P(axis),
            check_rep=False,
        )
        return fn(w_stack, xt, keys, lane_mask)
    fn = shard_map(
        lambda w, x, k, lm, xi: body(w, x, k, lm, x_index=xi),
        mesh=mesh,
        in_specs=(P(axis), P(), P(axis), P(axis), P(axis)),
        out_specs=P(axis),
        check_rep=False,
    )
    return fn(w_stack, xt, keys, lane_mask, x_index)


_SHARDED_STATICS = ("cfg", "use_scaling", "has_calib", "mesh", "axis")
_quantize_stack_sharded = partial(jax.jit, static_argnames=_SHARDED_STATICS)(
    _quantize_stack_sharded_impl)
# Donating twin for the sharded engine: under a >1-partition lowering JAX
# marks the donated stack `jax.buffer_donor`, a general donor XLA may
# recycle for any same-shard-sized transient (the BLC clip-grid residual
# copies are the big ones at production shapes) — no aliased output needed.
_quantize_stack_sharded_donate = partial(
    jax.jit, static_argnames=_SHARDED_STATICS,
    donate_argnames=("w_stack",))(_quantize_stack_sharded_impl)


def shard_count(mesh, axis: Optional[str] = None) -> Tuple[int, str]:
    """(n_shards, axis) for sharding a stack's leading dim over ``mesh``.
    ``axis=None`` picks the mesh's only axis (ambiguous meshes must name
    one)."""
    if axis is None:
        if len(mesh.axis_names) != 1:
            raise ValueError(
                f"mesh has axes {mesh.axis_names}; pass axis= explicitly")
        axis = mesh.axis_names[0]
    if axis not in mesh.axis_names:
        raise ValueError(f"axis {axis!r} not in mesh axes {mesh.axis_names}")
    return dict(zip(mesh.axis_names, mesh.devices.shape))[axis], axis


def _pad_lanes(arr: jax.Array, l_pad: int) -> jax.Array:
    """Pad the leading (lane) dim of ``arr`` up to ``l_pad`` by repeating
    the last lane — benign numerics for padding lanes whose results are
    masked off and sliced away."""
    L = arr.shape[0]
    if L == l_pad:
        return arr
    reps = jnp.broadcast_to(arr[-1:], (l_pad - L,) + arr.shape[1:])
    return jnp.concatenate([arr, reps], axis=0)


def _quantize_substack(
    w_stack: jax.Array,
    x_calib: jax.Array,
    x_index,
    keys: jax.Array,
    cfg: FLRQConfig,
    has_calib: bool,
    mesh,
    axis: Optional[str],
    donate: bool,
):
    """One (sub-)stack through the batched engine, including the scaling
    robustness gate (layers whose scaled pipeline lands above their own RTN
    floor are re-quantized without scaling in a second batched launch and
    the better result kept per layer). Returns the raw output dict of
    L-leading arrays — ``quantize_stack`` packs it (possibly concatenated
    across layer chunks)."""
    L = w_stack.shape[0]
    per_lane_x = x_calib.ndim == 3 and x_index is None
    # The scaling robustness gate may relaunch over the same stack — only
    # the launch that provably has no successor may donate it.
    may_relaunch = cfg.use_scaling and has_calib

    if mesh is not None:
        n_shards, axis = shard_count(mesh, axis)
        l_pad = -(-L // n_shards) * n_shards
        w_in = _pad_lanes(w_stack, l_pad)
        keys_in = _pad_lanes(keys, l_pad)
        x_in = _pad_lanes(x_calib, l_pad) if per_lane_x else x_calib
        idx_in = None if x_index is None else _pad_lanes(x_index, l_pad)
        lane_mask = jnp.arange(l_pad) < L

        def launch(use_scaling, donate_now=False):
            fn = (_quantize_stack_sharded_donate if donate_now
                  else _quantize_stack_sharded)
            out = fn(w_in, x_in, keys_in, lane_mask, idx_in, cfg=cfg,
                     use_scaling=use_scaling, has_calib=has_calib,
                     mesh=mesh, axis=axis)
            return {k: v[:L] for k, v in out.items()}
    else:
        lane_mask = jnp.ones((L,), jnp.bool_)

        def launch(use_scaling, donate_now=False):
            if donate_now:
                # Donation binds by aval, and the alias target (the f32
                # residual) must match — a bf16 stack donates the f32 copy
                # the pipeline materializes anyway (astype is the identity
                # for f32 inputs, so those donate the caller's buffer).
                out = dict(_quantize_stack_jit_donate(
                    w_stack.astype(jnp.float32), x_calib, keys, lane_mask,
                    x_index, cfg=cfg, use_scaling=use_scaling,
                    has_calib=has_calib, return_resid=True))
                out.pop("resid")  # alias target only; not a result
                return out
            return _quantize_stack_jit(
                w_stack, x_calib, keys, lane_mask, x_index, cfg=cfg,
                use_scaling=use_scaling, has_calib=has_calib)

    out = launch(cfg.use_scaling and has_calib,
                 donate_now=donate and not may_relaunch)
    if cfg.use_scaling and has_calib:
        gate = np.asarray(out["err_after"]) > np.asarray(out["err_before"])
        if gate.any():
            out2 = launch(False, donate_now=donate)
            redo = gate & (np.asarray(out2["err_after"])
                           < np.asarray(out["err_after"]))
            if redo.any():
                sel = jnp.asarray(redo)

                def pick(a, b):
                    return jnp.where(sel.reshape((L,) + (1,) * (a.ndim - 1)),
                                     b, a)

                out = jax.tree.map(pick, out, out2)
    return out


def quantize_stack(
    w_stack: jax.Array,
    x_calib: Optional[jax.Array],
    cfg: FLRQConfig,
    key: Optional[jax.Array] = None,
    name: str = "w",
    *,
    keys: Optional[jax.Array] = None,
    mesh=None,
    axis: Optional[str] = None,
    donate: bool = False,
    x_index: Optional[jax.Array] = None,
    layer_chunk: Optional[int] = None,
) -> Tuple[qtensor.QuantizedLinear, List[LayerStats]]:
    """Quantize an (L, m, n) stack of matrices in one (or, when the
    robustness gate trips, two) jitted launches per layer chunk.
    ``x_calib``: (tokens, n) calibration activations shared by the stack,
    (L, tokens, n) per-layer activations, or None. With ``x_index`` ((L,)
    int32), ``x_calib`` is a (U, tokens, n) stack of UNIQUE batches and
    each lane gathers ``x_calib[x_index[l]]`` inside the device program —
    the fused-stack driver passes one copy per group member instead of
    broadcasting to every lane.

    Mirrors ``quantize_matrix`` semantics per layer — including the
    robustness gate: layers whose scaled pipeline lands above their own RTN
    floor are re-quantized without scaling (as a second *batched* launch
    over the whole stack) and the better result is kept per layer.

    PRNG: pass either ``key`` (consumed as ``layer_key_chain(key, L)``) or
    precomputed per-layer ``keys`` (L, 2) — the latter lets a driver thread
    one chain across many stacks without re-deriving it.

    ``mesh``/``axis``: shard the stack's leading dim over that mesh axis
    (``shard_map``); each device quantizes its own slice, bit-identically
    to the single-device program (L is padded up to the shard count with
    masked lanes when it does not divide).

    ``layer_chunk=K`` runs the batched engine body over ceil(L/K) lane
    chunks instead of one (L, m, n) launch, bounding the per-epoch f32
    transients (BLC residuals, candidate round-trips) at (K, m, n). The
    PRNG chain is per-lane, so the output is bit-identical to the unchunked
    launch; chunking composes with ``mesh`` (each chunk shard_maps) and
    with ``donate`` — with the caveat that chunked donation recycles each
    (K, m, n) chunk *copy* per launch while the full stack stays resident
    until its last chunk is sliced off (then it is freed); the (L, m, n)
    saving of the unchunked donate path applies only to the final chunk's
    launch. That is the right trade at production shapes: chunking exists
    to bound the L-scaled transients, which dwarf one weight stack.

    ``donate=True`` CONSUMES the ``w_stack`` buffer (standard jax donation
    semantics — the caller must not reuse it): the last launch that needs
    the stack donates it, dropping peak memory by one (L, m, n) f32 copy.
    Single-device, the donation aliases the stack into the quantization
    residual output; sharded, the stack shards become `jax.buffer_donor`s
    XLA recycles for the clip-grid transients. The stacked-model driver
    passes its transposed quantizer-orientation temporaries here.

    Returns a stacked QuantizedLinear (U/V padded to the realized max rank;
    zero columns are numerically inert) and per-layer LayerStats.
    """
    t0 = time.perf_counter()
    L, m, n = w_stack.shape
    if x_calib is None:
        x_calib = jnp.zeros((0, n), jnp.float32)
    has_calib = x_calib.shape[-2] > 0

    if (key is None) == (keys is None):
        raise ValueError("pass exactly one of `key` or `keys`")
    if keys is None:
        keys, _ = layer_key_chain(key, L)

    per_lane_x = x_calib.ndim == 3 and x_index is None
    chunk = L if not layer_chunk else max(1, min(int(layer_chunk), L))
    if chunk >= L:
        out = _quantize_substack(w_stack, x_calib, x_index, keys, cfg,
                                 has_calib, mesh, axis, donate)
    else:
        parts = []
        for i0 in range(0, L, chunk):
            i1 = min(i0 + chunk, L)
            w_sub = w_stack[i0:i1]
            if donate and i1 == L and hasattr(w_stack, "delete"):
                # last chunk sliced off — the donated stack is fully
                # consumed, so free it before the final launch's transients
                w_stack.delete()
            parts.append(_quantize_substack(
                w_sub,
                x_calib[i0:i1] if per_lane_x else x_calib,
                None if x_index is None else x_index[i0:i1],
                keys[i0:i1], cfg, has_calib, mesh, axis, donate))
        out = {k: jnp.concatenate([p[k] for p in parts]) for k in parts[0]}

    ranks = np.asarray(out["ranks"])
    rmax = max(int(ranks.max()), 1)
    spec = cfg.spec()
    qt = qtensor.QuantizedLinear(
        packed=out["packed"],
        scale=out["scale"],
        zp=out["zp"],
        u=out["u"][:, :, :rmax].astype(cfg.store_dtype),
        v=out["v"][:, :rmax, :].astype(cfg.store_dtype),
        act_scale_inv=out["act_scale_inv"].astype(cfg.store_dtype),
        bits=spec.bits, group_size=spec.group_size,
        symmetric=spec.symmetric, m=m, n=n,
    )
    dt = time.perf_counter() - t0
    err_b = np.asarray(out["err_before"])
    err_a = np.asarray(out["err_after"])
    clips = np.asarray(out["clip"])
    stats = [
        LayerStats(
            name=f"{name}[{i}]", shape=(m, n), rank=int(ranks[i]),
            err_before=float(err_b[i]), err_after=float(err_a[i]),
            extra_bits=qtensor.extra_avg_bits(int(ranks[i]), m, n),
            clip=float(clips[i]), seconds=dt / L,
        )
        for i in range(L)
    ]
    return qt, stats


# ---------------------------------------------------------------------------
# Whole-model driver
# ---------------------------------------------------------------------------

def default_predicate(path: str, leaf) -> bool:
    """Quantize 2-D float matrices except embeddings / norms / tiny params."""
    if not hasattr(leaf, "ndim") or leaf.ndim != 2:
        return False
    if leaf.dtype not in (jnp.float32, jnp.bfloat16, jnp.float16):
        return False
    lname = path.lower()
    if any(s in lname for s in ("embed", "norm", "scale", "bias", "router")):
        return False
    m, n = leaf.shape
    return m >= 128 and n >= 128 and (n % 128 == 0)


def _flatten_with_paths(tree) -> Dict[str, Any]:
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        flat[jax.tree_util.keystr(path)] = leaf
    return flat


def quantize_model(
    params,
    calib_acts: Optional[Dict[str, jax.Array]],
    cfg: FLRQConfig,
    predicate: Callable[[str, Any], bool] = default_predicate,
    progress: Optional[Callable[[str, LayerStats], None]] = None,
):
    """Walk a parameter pytree; replace matching 2-D matrices with
    QuantizedLinear. ``calib_acts`` maps the same key-paths to (tokens, n)
    activation batches (missing entries → no calibration for that layer).

    Returns (quantized_tree, {path: LayerStats}).
    """
    key = jax.random.PRNGKey(cfg.seed)
    stats: Dict[str, LayerStats] = {}
    flat_paths = _flatten_with_paths(params)
    n_target = sum(1 for p, l in flat_paths.items() if predicate(p, l))
    keys = iter(jax.random.split(key, max(n_target, 1)))

    def visit(path, leaf):
        pstr = jax.tree_util.keystr(path)
        if not predicate(pstr, leaf):
            return leaf
        xc = None
        if calib_acts:
            xc = calib_acts.get(pstr)
        qt, st = quantize_matrix(leaf, xc, cfg, next(keys), name=pstr)
        stats[pstr] = st
        if progress:
            progress(pstr, st)
        return qt

    qtree = jax.tree_util.tree_map_with_path(visit, params)
    return qtree, stats


def model_report(stats: Dict[str, LayerStats]) -> Dict[str, float]:
    """Aggregate stats (paper Tables 3/9 style: avg rank, extra bits)."""
    if not stats:
        return dict(layers=0, avg_rank=0.0, avg_extra_bits=0.0,
                    mean_err_before=0.0, mean_err_after=0.0, seconds=0.0)
    n = len(stats)
    return dict(
        layers=n,
        avg_rank=sum(s.rank for s in stats.values()) / n,
        avg_extra_bits=sum(s.extra_bits for s in stats.values()) / n,
        mean_err_before=sum(s.err_before for s in stats.values()) / n,
        mean_err_after=sum(s.err_after for s in stats.values()) / n,
        seconds=sum(s.seconds for s in stats.values()),
    )
