"""FLRQ orchestration: per-matrix quantizer, batched per-stack quantizer,
and whole-model driver (paper Alg. 2: scaling → R1-FLR → clipping → BLC →
pack).

The per-matrix pipeline:

  1. activation scaling  α = awq_scale(mean|X|)  (Eq. 10-11), W_s = W·diag(α),
     X_s = diag(α)⁻¹·X  (output-equivalent reparameterization);
  2. R1-FLR on W_s selects the rank r and initial (U, V);
  3. BLC alternates (re-sketch quant residual, re-clip, re-quant) keeping the
     best E = ||W_s X_s − (W_r + W_q) X_s||;
  4. the winner is packed into a QuantizedLinear (α⁻¹ folded into the
     runtime input scaling).

``quantize_stack`` runs the same pipeline for all L layers of a stacked
(L, m, n) tensor as ONE jitted device program (vmapped R1-FLR with the
device-side stopping rule, batched BLC with per-layer rank masking, batched
clip search / qparams / bit-packing) — no per-peel host syncs, no per-layer
dispatch storms. This is the engine behind the default path of
``repro.quant.stacked.quantize_model_stacked``.

``quantize_model`` maps the per-matrix pipeline over every 2-D parameter of
a model pytree that matches the quantization predicate (min size, not
embeddings/norms), producing a parallel pytree of QuantizedLinear + a stats
report that the benchmarks and EXPERIMENTS.md consume.
"""
from __future__ import annotations

import dataclasses
import time
from functools import partial
from typing import Any, Callable, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from .blc import blc as _run_blc
from .blc import blc_batched as _run_blc_batched
from .flr import (
    FLRConfig,
    flexible_rank_select_batched,
    flexible_rank_select_py,
    split_chain,
)
from .quantize import (
    QuantSpec,
    awq_scale,
    channel_mean_abs,
    compute_qparams,
    pseudo_quantize,
    quantize_codes,
    recon_error,
    search_clip_ratio,
)
from ..quant import qtensor


@dataclasses.dataclass(frozen=True)
class FLRQConfig:
    bits: int = 4
    group_size: int = 128
    symmetric: bool = False
    x: float = 0.2               # memory budget (paper default)
    t: float = 1e-4              # amax slope threshold
    it: int = 2                  # sketch power iterations (paper default)
    max_rank: int = 128
    blc_epochs: int = 8          # paper: 1 suffices at 3/4-bit, ~20 at 2-bit
    use_scaling: bool = True
    use_blc: bool = True
    seed: int = 0
    store_dtype: Any = jnp.bfloat16
    backend: str = "xla"         # sketch backend: "xla" | "pallas" | "auto"

    def flr(self) -> FLRConfig:
        return FLRConfig(
            bits=self.bits, x=self.x, t=self.t, it=self.it,
            max_rank=self.max_rank, backend=self.backend,
        )

    def spec(self) -> QuantSpec:
        return QuantSpec(self.bits, self.group_size, self.symmetric)

    def recommended_blc_epochs(self) -> int:
        # Paper Table 22: BLC converges in ~1 epoch at 3/4-bit, ~20 at 2-bit.
        return max(self.blc_epochs, 20) if self.bits <= 2 else self.blc_epochs


@dataclasses.dataclass
class LayerStats:
    name: str
    shape: Tuple[int, int]
    rank: int
    err_before: float      # RTN error at same bits (no low-rank, no scaling)
    err_after: float       # FLRQ error
    extra_bits: float
    clip: float
    seconds: float


def quantize_matrix(
    w: jax.Array,
    x_calib: Optional[jax.Array],
    cfg: FLRQConfig,
    key: jax.Array,
    name: str = "w",
) -> Tuple[qtensor.QuantizedLinear, LayerStats]:
    """Quantize one (m, n) matrix. ``x_calib``: (tokens, n) calibration
    activations feeding this matrix (None → unit scaling + Frobenius
    objectives).

    Robustness gate: activation scaling (Eq. 10-11) is heuristic — if the
    scaled pipeline ends up worse than the unscaled RTN floor, we redo the
    pipeline without scaling and keep the better result (a production
    quantizer must never regress below its own trivial baseline).
    """
    qt, st = _quantize_matrix_once(w, x_calib, cfg, key, name)
    if cfg.use_scaling and st.err_after > st.err_before:
        cfg2 = dataclasses.replace(cfg, use_scaling=False)
        qt2, st2 = _quantize_matrix_once(w, x_calib, cfg2, key, name)
        if st2.err_after < st.err_after:
            st2.seconds += st.seconds
            return qt2, st2
    return qt, st


def _quantize_matrix_once(
    w: jax.Array,
    x_calib: Optional[jax.Array],
    cfg: FLRQConfig,
    key: jax.Array,
    name: str = "w",
) -> Tuple[qtensor.QuantizedLinear, LayerStats]:
    t0 = time.perf_counter()
    m, n = w.shape
    spec = cfg.spec()
    w32 = w.astype(jnp.float32)

    if x_calib is None:
        x_calib = jnp.zeros((0, n), jnp.float32)
    xt = x_calib.astype(jnp.float32)

    # --- (1) activation scaling ------------------------------------------
    if cfg.use_scaling and xt.shape[0] > 0:
        alpha = awq_scale(channel_mean_abs(xt))
    else:
        alpha = jnp.ones((n,), jnp.float32)
    ws = w32 * alpha[None, :]
    xs = (xt / alpha[None, :]).T  # (n, tokens) column-batch in scaled space
    if xs.shape[1] == 0:
        xs_obj = jnp.eye(n, dtype=jnp.float32)  # Frobenius objective
    else:
        xs_obj = xs

    # --- baseline error (plain RTN, for the stats report) ----------------
    err_before = float(recon_error(w32, pseudo_quantize(w32, spec), xt.T if xt.shape[0] else None))

    # --- (2) flexible rank selection --------------------------------------
    key, k_flr, k_blc = jax.random.split(key, 3)
    u, v, rank, _trace = flexible_rank_select_py(ws, k_flr, cfg.flr())

    # --- (3)+(4) BLC (or single-shot clip+quant if disabled) --------------
    if cfg.use_blc:
        res = _run_blc(
            ws, xs_obj, k_blc, spec, rank,
            epochs=cfg.recommended_blc_epochs(), it=cfg.it,
            backend=cfg.backend,
        )
        u, v, clip = res.u, res.v, res.clip
        wq_deq = res.w_q
        err_after = float(res.err)
    else:
        resid = ws - (u @ v if rank else 0.0)
        clip = search_clip_ratio(resid, xs_obj, spec)
        wq_deq = pseudo_quantize(resid, spec, clip)
        err_after = float(recon_error(ws, wq_deq + (u @ v if rank else 0.0), xs_obj))
        clip = jnp.asarray(clip)

    # --- pack --------------------------------------------------------------
    resid_final = ws - (u @ v if rank else jnp.zeros_like(ws))
    scale, zp = compute_qparams(resid_final, spec, clip)
    codes = quantize_codes(resid_final, spec, scale, zp)
    if rank == 0:
        u = jnp.zeros((m, 0), jnp.float32)
        v = jnp.zeros((0, n), jnp.float32)
    qt = qtensor.from_parts(
        codes, scale, zp, u, v, spec,
        act_scale_inv=1.0 / alpha, store_dtype=cfg.store_dtype,
    )
    stats = LayerStats(
        name=name,
        shape=(m, n),
        rank=int(rank),
        err_before=err_before,
        err_after=err_after,
        extra_bits=qt.extra_avg_bits(),
        clip=float(clip),
        seconds=time.perf_counter() - t0,
    )
    return qt, stats


# ---------------------------------------------------------------------------
# Batched per-stack engine (all L layers of a stacked tensor in one program)
# ---------------------------------------------------------------------------

# Per-layer PRNG discipline = the per-peel discipline (one definition,
# flr.split_chain): quantize_stack consumes it and the stacked driver
# advances its cross-tensor chain with it, keeping both engines in sync.
layer_key_chain = split_chain

@partial(jax.jit, static_argnames=("cfg", "use_scaling", "has_calib"))
def _quantize_stack_jit(
    w_stack: jax.Array,   # (L, m, n) f32, quantizer orientation (m=out)
    xt: jax.Array,        # (tokens, n) calibration acts (tokens may be 0)
    keys: jax.Array,      # (L, 2) per-layer PRNG keys
    cfg: FLRQConfig,
    use_scaling: bool,
    has_calib: bool,
):
    """The whole FLRQ pipeline for a layer stack as ONE device program:
    batched scaling → vmapped R1-FLR (device-side stopping) → batched BLC
    (rank-masked blocked re-sketch) or batched clip search → batched
    qparams/codes/bit-packing. Returns a dict of L-leading arrays."""
    L, m, n = w_stack.shape
    spec = cfg.spec()
    w32 = w_stack.astype(jnp.float32)
    xt = xt.astype(jnp.float32)

    # --- (1) activation scaling (shared: the stack sees one calib batch) ---
    if use_scaling and has_calib:
        alpha = awq_scale(channel_mean_abs(xt))
    else:
        alpha = jnp.ones((n,), jnp.float32)
    ws = w32 * alpha[None, None, :]
    if has_calib:
        xs_obj = (xt / alpha[None, :]).T      # (n, tokens), scaled space
        x_err = xt.T                          # unscaled-space error objective
    else:
        xs_obj = jnp.eye(n, dtype=jnp.float32)  # Frobenius objective
        x_err = None

    # --- baseline error (plain RTN per layer, for the stats report) --------
    err_before = jax.vmap(
        lambda wl: recon_error(wl, pseudo_quantize(wl, spec), x_err))(w32)

    # --- per-layer keys: same split discipline as quantize_matrix ----------
    k3 = jax.vmap(lambda k: jax.random.split(k, 3))(keys)  # (L, 3, 2)
    k_flr, k_blc = k3[:, 1], k3[:, 2]

    # --- (2) flexible rank selection: one launch for the whole stack -------
    flr = flexible_rank_select_batched(ws, k_flr, cfg.flr())
    ranks = flr.rank                           # (L,) int32
    max_r = flr.u.shape[-1]                    # static buffer width

    # --- (3)+(4) BLC (or single-shot clip+quant if disabled) ---------------
    if cfg.use_blc:
        res = _run_blc_batched(
            ws, xs_obj, k_blc, spec, ranks, max_r,
            epochs=cfg.recommended_blc_epochs(), it=cfg.it,
            backend=cfg.backend,
        )
        u, v, clip, err_after = res.u, res.v, res.clip, res.err
    else:
        u, v = flr.u.astype(jnp.float32), flr.v.astype(jnp.float32)
        resid = ws - u @ v

        def one(resid_l):
            c = search_clip_ratio(resid_l, xs_obj, spec)
            return c, pseudo_quantize(resid_l, spec, c)

        clip, wq = jax.vmap(one)(resid)
        err_after = jax.vmap(
            lambda wl, wh: recon_error(wl, wh, xs_obj))(ws, wq + u @ v)

    # --- pack ---------------------------------------------------------------
    resid_final = ws - u @ v
    scale, zp = jax.vmap(
        lambda r, c: compute_qparams(r, spec, c))(resid_final, clip)
    codes = jax.vmap(
        lambda r, s, z: quantize_codes(r, spec, s, z))(resid_final, scale, zp)
    packed = qtensor.pack_codes(codes, spec)
    return dict(
        packed=packed, scale=scale, zp=zp, u=u, v=v,
        act_scale_inv=jnp.broadcast_to(1.0 / alpha, (L, n)),
        ranks=ranks, clip=clip,
        err_before=err_before, err_after=err_after,
    )


def quantize_stack(
    w_stack: jax.Array,
    x_calib: Optional[jax.Array],
    cfg: FLRQConfig,
    key: Optional[jax.Array] = None,
    name: str = "w",
    *,
    keys: Optional[jax.Array] = None,
) -> Tuple[qtensor.QuantizedLinear, List[LayerStats]]:
    """Quantize an (L, m, n) stack of matrices in one (or, when the
    robustness gate trips, two) jitted launches. ``x_calib``: (tokens, n)
    calibration activations shared by the stack, or None.

    Mirrors ``quantize_matrix`` semantics per layer — including the
    robustness gate: layers whose scaled pipeline lands above their own RTN
    floor are re-quantized without scaling (as a second *batched* launch
    over the whole stack) and the better result is kept per layer.

    PRNG: pass either ``key`` (consumed as ``layer_key_chain(key, L)``) or
    precomputed per-layer ``keys`` (L, 2) — the latter lets a driver thread
    one chain across many stacks without re-deriving it.

    Returns a stacked QuantizedLinear (U/V padded to the realized max rank;
    zero columns are numerically inert) and per-layer LayerStats.
    """
    t0 = time.perf_counter()
    L, m, n = w_stack.shape
    if x_calib is None:
        x_calib = jnp.zeros((0, n), jnp.float32)
    has_calib = x_calib.shape[0] > 0

    if (key is None) == (keys is None):
        raise ValueError("pass exactly one of `key` or `keys`")
    if keys is None:
        keys, _ = layer_key_chain(key, L)

    out = _quantize_stack_jit(
        w_stack, x_calib, keys, cfg, cfg.use_scaling and has_calib, has_calib)
    if cfg.use_scaling and has_calib:
        gate = np.asarray(out["err_after"]) > np.asarray(out["err_before"])
        if gate.any():
            out2 = _quantize_stack_jit(
                w_stack, x_calib, keys, cfg, False, has_calib)
            redo = gate & (np.asarray(out2["err_after"])
                           < np.asarray(out["err_after"]))
            if redo.any():
                sel = jnp.asarray(redo)

                def pick(a, b):
                    return jnp.where(sel.reshape((L,) + (1,) * (a.ndim - 1)),
                                     b, a)

                out = jax.tree.map(pick, out, out2)

    ranks = np.asarray(out["ranks"])
    rmax = max(int(ranks.max()), 1)
    spec = cfg.spec()
    qt = qtensor.QuantizedLinear(
        packed=out["packed"],
        scale=out["scale"],
        zp=out["zp"],
        u=out["u"][:, :, :rmax].astype(cfg.store_dtype),
        v=out["v"][:, :rmax, :].astype(cfg.store_dtype),
        act_scale_inv=out["act_scale_inv"].astype(cfg.store_dtype),
        bits=spec.bits, group_size=spec.group_size,
        symmetric=spec.symmetric, m=m, n=n,
    )
    dt = time.perf_counter() - t0
    err_b = np.asarray(out["err_before"])
    err_a = np.asarray(out["err_after"])
    clips = np.asarray(out["clip"])
    stats = [
        LayerStats(
            name=f"{name}[{i}]", shape=(m, n), rank=int(ranks[i]),
            err_before=float(err_b[i]), err_after=float(err_a[i]),
            extra_bits=qtensor.extra_avg_bits(int(ranks[i]), m, n),
            clip=float(clips[i]), seconds=dt / L,
        )
        for i in range(L)
    ]
    return qt, stats


# ---------------------------------------------------------------------------
# Whole-model driver
# ---------------------------------------------------------------------------

def default_predicate(path: str, leaf) -> bool:
    """Quantize 2-D float matrices except embeddings / norms / tiny params."""
    if not hasattr(leaf, "ndim") or leaf.ndim != 2:
        return False
    if leaf.dtype not in (jnp.float32, jnp.bfloat16, jnp.float16):
        return False
    lname = path.lower()
    if any(s in lname for s in ("embed", "norm", "scale", "bias", "router")):
        return False
    m, n = leaf.shape
    return m >= 128 and n >= 128 and (n % 128 == 0)


def _flatten_with_paths(tree) -> Dict[str, Any]:
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        flat[jax.tree_util.keystr(path)] = leaf
    return flat


def quantize_model(
    params,
    calib_acts: Optional[Dict[str, jax.Array]],
    cfg: FLRQConfig,
    predicate: Callable[[str, Any], bool] = default_predicate,
    progress: Optional[Callable[[str, LayerStats], None]] = None,
):
    """Walk a parameter pytree; replace matching 2-D matrices with
    QuantizedLinear. ``calib_acts`` maps the same key-paths to (tokens, n)
    activation batches (missing entries → no calibration for that layer).

    Returns (quantized_tree, {path: LayerStats}).
    """
    key = jax.random.PRNGKey(cfg.seed)
    stats: Dict[str, LayerStats] = {}
    flat_paths = _flatten_with_paths(params)
    n_target = sum(1 for p, l in flat_paths.items() if predicate(p, l))
    keys = iter(jax.random.split(key, max(n_target, 1)))

    def visit(path, leaf):
        pstr = jax.tree_util.keystr(path)
        if not predicate(pstr, leaf):
            return leaf
        xc = None
        if calib_acts:
            xc = calib_acts.get(pstr)
        qt, st = quantize_matrix(leaf, xc, cfg, next(keys), name=pstr)
        stats[pstr] = st
        if progress:
            progress(pstr, st)
        return qt

    qtree = jax.tree_util.tree_map_with_path(visit, params)
    return qtree, stats


def model_report(stats: Dict[str, LayerStats]) -> Dict[str, float]:
    """Aggregate stats (paper Tables 3/9 style: avg rank, extra bits)."""
    if not stats:
        return dict(layers=0, avg_rank=0.0, avg_extra_bits=0.0,
                    mean_err_before=0.0, mean_err_after=0.0, seconds=0.0)
    n = len(stats)
    return dict(
        layers=n,
        avg_rank=sum(s.rank for s in stats.values()) / n,
        avg_extra_bits=sum(s.extra_bits for s in stats.values()) / n,
        mean_err_before=sum(s.err_before for s in stats.values()) / n,
        mean_err_after=sum(s.err_after for s in stats.values()) / n,
        seconds=sum(s.seconds for s in stats.values()),
    )
