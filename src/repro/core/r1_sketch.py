"""R1-Sketch: rank-1 randomized-SVD sketching (paper Eq. 5-7, 13-14).

The paper's core efficiency contribution. For a matrix A and a Gaussian
vector s, run ``it`` power iterations:

    P = (A A^T)^it A s,    K = A^T P

then the dominant rank-1 component of A is

    A_L = (||K|| / ||P||) * P / ||P||   (m-vector)
    A_R = K / ||K||                      (n-vector)

and  A ≈ A_L A_R^T + residual.  Peeling this repeatedly from the residual
builds an incremental low-rank approximation whose rank can be decided
*while* sketching — the property R1-FLR exploits.

Three implementations live here:
  * ``rank1_sketch``        one rank-1 step (jitted building block)
  * ``sketch_lowrank``      fixed-rank peel via lax.scan (jittable end-to-end)
  * ``sketch_lowrank_block``  beyond-paper blocked variant (block power
    iteration + QR): sketches ``block`` directions per pass, turning GEMV
    into GEMM for the MXU. Same peel semantics at block=1.

A Pallas TPU kernel version of the inner step is in
``repro.kernels.r1_sketch`` (VMEM-resident A tile across all 2it+2 GEMVs).
"""
from __future__ import annotations

from functools import partial
from typing import Tuple

import jax
import jax.numpy as jnp

_EPS = 1e-20


@partial(jax.jit, static_argnames=("it",))
def rank1_sketch(a: jax.Array, key: jax.Array, it: int = 2) -> Tuple[jax.Array, jax.Array]:
    """One R1-Sketch step. Returns (u, v) with a ≈ outer(u, v) + residual.

    Cost: exactly 2*it + 2 matrix-vector products (paper: "6 GEMV" at it=2).
    """
    a32 = a.astype(jnp.float32)
    s = jax.random.normal(key, (a.shape[1],), jnp.float32)
    p = a32 @ s
    # The A_L/A_R formulas (Eq. 7) are invariant to the scale of P, so we
    # renormalize between power iterations — without this, ||P|| grows as
    # sigma_1^(2it+1) and overflows f32 for large / activation-scaled
    # matrices.
    p = p / jnp.maximum(jnp.linalg.norm(p), _EPS)
    for _ in range(it):  # unrolled: `it` is tiny and static
        p = a32 @ (a32.T @ p)
        p = p / jnp.maximum(jnp.linalg.norm(p), _EPS)
    k = a32.T @ p  # with ||P|| = 1:  A_L = ||K|| * P,  A_R = K / ||K||
    kn = jnp.maximum(jnp.linalg.norm(k), _EPS)
    u = p * kn
    v = k / kn
    return u.astype(a.dtype), v.astype(a.dtype)


@partial(jax.jit, static_argnames=("rank", "it"))
def sketch_lowrank(
    a: jax.Array, key: jax.Array, rank: int, it: int = 2
) -> Tuple[jax.Array, jax.Array]:
    """Peel ``rank`` rank-1 components. Returns (U (m,r), V (r,n)) such that
    a ≈ U @ V. Fully jittable (lax.scan over the peel)."""
    keys = jax.random.split(key, rank)

    def body(residual, k):
        u, v = rank1_sketch(residual, k, it=it)
        residual = residual - jnp.outer(u, v).astype(residual.dtype)
        return residual, (u, v)

    _, (us, vs) = jax.lax.scan(body, a, keys)
    return jnp.transpose(us), vs  # (m, r), (r, n)


@partial(jax.jit, static_argnames=("rank", "block", "it"))
def sketch_lowrank_block(
    a: jax.Array, key: jax.Array, rank: int, block: int = 8, it: int = 2
) -> Tuple[jax.Array, jax.Array]:
    """Beyond-paper: block power iteration (randomized subspace iteration)
    peeling ``block`` directions per pass. GEMM-shaped for the MXU; QR keeps
    the block orthonormal. Produces (U (m,r), V (r,n)); rank must be a
    multiple of block."""
    if rank % block:
        raise ValueError(f"rank={rank} must be a multiple of block={block}")
    n_steps = rank // block
    keys = jax.random.split(key, n_steps)

    def body(residual, k):
        r32 = residual.astype(jnp.float32)
        s = jax.random.normal(k, (residual.shape[1], block), jnp.float32)
        p = r32 @ s
        for _ in range(it):
            p, _ = jnp.linalg.qr(p)  # stabilize between power iterations
            p = r32 @ (r32.T @ p)
        q, _ = jnp.linalg.qr(p)  # (m, block) orthonormal basis
        b = q.T @ r32  # (block, n)
        u = q.astype(residual.dtype)
        v = b.astype(residual.dtype)
        residual = residual - (u @ v).astype(residual.dtype)
        return residual, (u, v)

    _, (us, vs) = jax.lax.scan(body, a, keys)
    u = jnp.transpose(us, (1, 0, 2)).reshape(a.shape[0], rank)
    v = vs.reshape(rank, a.shape[1])
    return u, v


def sketch_apply(u: jax.Array, v: jax.Array, x: jax.Array) -> jax.Array:
    """(U V) @ x computed low-rank-wise: U @ (V @ x)."""
    return u @ (v @ x)
