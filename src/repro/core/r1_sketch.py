"""R1-Sketch: rank-1 randomized-SVD sketching (paper Eq. 5-7, 13-14).

The paper's core efficiency contribution. For a matrix A and a Gaussian
vector s, run ``it`` power iterations:

    P = (A A^T)^it A s,    K = A^T P

then the dominant rank-1 component of A is

    A_L = (||K|| / ||P||) * P / ||P||   (m-vector)
    A_R = K / ||K||                      (n-vector)

and  A ≈ A_L A_R^T + residual.  Peeling this repeatedly from the residual
builds an incremental low-rank approximation whose rank can be decided
*while* sketching — the property R1-FLR exploits.

Implementations:
  * ``rank1_sketch``        one rank-1 step (jitted building block)
  * ``sketch_lowrank``      fixed-rank peel via lax.scan (jittable end-to-end)
  * ``sketch_lowrank_block``  beyond-paper blocked variant (block power
    iteration + QR): sketches ``block`` directions per pass, turning GEMV
    into GEMM for the MXU. Same peel semantics at block=1; handles
    rank % block != 0 with one trailing partial block.
  * ``sketch_lowrank_block_masked``  fixed ``max_rank`` buffers with a
    *traced* effective rank: components with index >= rank are zeroed.
    This is what lets the batched BLC vmap one program over layers whose
    R1-FLR-selected ranks differ.

Backends: every sketch entry point takes ``backend``:
  * ``"xla"``    (default) plain jnp contractions;
  * ``"pallas"`` force the Pallas TPU kernels from ``repro.kernels.r1_sketch``
    (the 2·it+2 contraction chain streams A through VMEM once per pass);
    off-TPU this runs in interpret mode — numerics-equivalent, slow;
  * ``"auto"``   Pallas on TPU when the shape tiles, else XLA.
"""
from __future__ import annotations

from functools import partial
from typing import Tuple

import jax
import jax.numpy as jnp

_EPS = 1e-20

BACKENDS = ("xla", "pallas", "auto")


def _kernel_shape_ok(m: int, n: int) -> bool:
    """The Pallas sketch kernels tile A as (min(256,m), min(512,n)) blocks
    (and the transposed pass as (min(256,m), min(512,n))) — both dims must
    divide evenly."""
    return (m % min(256, m) == 0) and (n % min(512, n) == 0)


def resolve_backend(backend: str, shape) -> str:
    """Map a user backend choice to a concrete execution mode:
    "xla" | "pallas" | "pallas_interpret" (forced Pallas off-TPU)."""
    if backend not in BACKENDS:
        raise ValueError(f"backend={backend!r} not in {BACKENDS}")
    if backend == "xla":
        return "xla"
    m, n = int(shape[0]), int(shape[1])
    if not _kernel_shape_ok(m, n):
        if backend == "pallas":
            raise ValueError(
                f"backend='pallas' but shape ({m}, {n}) does not tile the "
                "sketch kernels; use backend='auto' for automatic fallback")
        return "xla"
    on_tpu = jax.default_backend() == "tpu"
    if backend == "pallas":
        return "pallas" if on_tpu else "pallas_interpret"
    return "pallas" if on_tpu else "xla"  # auto


def _power_iter(a32: jax.Array, s: jax.Array, it: int, mode: str):
    """(p, k) with p the normalized power-iterate and k = Aᵀp. ``s`` may be
    (n,) or (n, b). Cost: 2·it + 2 passes over A in every mode."""
    if mode == "xla":
        p = a32 @ s
        # The A_L/A_R formulas (Eq. 7) are invariant to the scale of P, so we
        # renormalize between power iterations — without this, ||P|| grows as
        # sigma_1^(2it+1) and overflows f32 for large / activation-scaled
        # matrices.
        p = p / jnp.maximum(jnp.linalg.norm(p, axis=0, keepdims=s.ndim == 2),
                            _EPS)
        for _ in range(it):  # unrolled: `it` is tiny and static
            p = a32 @ (a32.T @ p)
            p = p / jnp.maximum(
                jnp.linalg.norm(p, axis=0, keepdims=s.ndim == 2), _EPS)
        return p, a32.T @ p
    from ..kernels.r1_sketch import power_iter as kernel_power_iter
    return kernel_power_iter(a32, s, it=it, interpret=mode == "pallas_interpret")


@partial(jax.jit, static_argnames=("it", "backend"))
def rank1_sketch(
    a: jax.Array, key: jax.Array, it: int = 2, backend: str = "xla"
) -> Tuple[jax.Array, jax.Array]:
    """One R1-Sketch step. Returns (u, v) with a ≈ outer(u, v) + residual.

    Cost: exactly 2*it + 2 matrix-vector products (paper: "6 GEMV" at it=2).
    """
    a32 = a.astype(jnp.float32)
    s = jax.random.normal(key, (a.shape[1],), jnp.float32)
    mode = resolve_backend(backend, a.shape)
    p, k = _power_iter(a32, s, it, mode)
    kn = jnp.maximum(jnp.linalg.norm(k), _EPS)
    u = p * kn  # with ||P|| = 1:  A_L = ||K|| * P,  A_R = K / ||K||
    v = k / kn
    return u.astype(a.dtype), v.astype(a.dtype)


# Greedy rank-1 deflation loses accuracy at very small ranks: each peel
# commits to the sketch's noisy estimate of the current dominant direction,
# and with only a handful of components there is no later peel to absorb
# the error (rank 4 lands ~50% above truncated SVD on LLM-like spectra).
# Below this rank we switch to one oversampled subspace iteration instead.
_OVERSAMPLED_MAX_RANK = 8
_OVERSAMPLE = 8


def _sketch_oversampled(a32, key, rank: int, it: int):
    """Oversampled block sketch (randomized subspace iteration): capture a
    (rank + oversample)-dim subspace in one pass stack, then truncate to
    ``rank`` via the small SVD of the projected factor. Matches truncated
    SVD to ~1e-6 relative at ranks the greedy peel can't reach.

    Always returns exactly (m, rank)/(rank, n) — when rank > min(m, n)
    only min(m, n) components exist and the rest are zero-padded (inert),
    matching the peel path's fixed-width contract."""
    m, n = a32.shape
    r = min(rank + _OVERSAMPLE, m, n)
    s = jax.random.normal(key, (n, r), jnp.float32)
    p = a32 @ s
    for _ in range(it):
        q, _ = jnp.linalg.qr(p)  # stabilize between power iterations
        p = a32 @ (a32.T @ q)
    q, _ = jnp.linalg.qr(p)  # (m, r) orthonormal basis
    b = q.T @ a32            # (r, n)
    ub, sb, vtb = jnp.linalg.svd(b, full_matrices=False)
    keep = min(rank, r)
    u = (q @ ub[:, :keep]) * sb[:keep]
    v = vtb[:keep, :]
    if keep < rank:
        u = jnp.pad(u, ((0, 0), (0, rank - keep)))
        v = jnp.pad(v, ((0, rank - keep), (0, 0)))
    return u, v


@partial(jax.jit, static_argnames=("rank", "it", "backend"))
def sketch_lowrank(
    a: jax.Array, key: jax.Array, rank: int, it: int = 2, backend: str = "xla"
) -> Tuple[jax.Array, jax.Array]:
    """Rank-``rank`` sketch. Returns (U (m,r), V (r,n)) such that
    a ≈ U @ V. Fully jittable.

    Ranks ≤ 8 use the oversampled subspace iteration (greedy rank-1
    deflation is measurably worse than SVD there — see ROADMAP note);
    larger ranks peel rank-1 components via lax.scan, whose incremental
    structure is what R1-FLR's while-sketching rank decision exploits.
    """
    if 0 < rank <= _OVERSAMPLED_MAX_RANK:
        u, v = _sketch_oversampled(a.astype(jnp.float32), key, rank, it)
        return u.astype(a.dtype), v.astype(a.dtype)
    keys = jax.random.split(key, rank)

    def body(residual, k):
        u, v = rank1_sketch(residual, k, it=it, backend=backend)
        residual = residual - jnp.outer(u, v).astype(residual.dtype)
        return residual, (u, v)

    _, (us, vs) = jax.lax.scan(body, a, keys)
    return jnp.transpose(us), vs  # (m, r), (r, n)


def _block_step(residual, k, block: int, it: int, mode: str):
    """One block power-iteration peel: returns (u (m, block), v (block, n))
    spanning the dominant ``block``-dim subspace of the residual."""
    r32 = residual.astype(jnp.float32)
    s = jax.random.normal(k, (residual.shape[1], block), jnp.float32)
    if mode == "xla":
        p = r32 @ s
        for _ in range(it):
            p, _ = jnp.linalg.qr(p)  # stabilize between power iterations
            p = r32 @ (r32.T @ p)
        q, _ = jnp.linalg.qr(p)  # (m, block) orthonormal basis
        b = q.T @ r32  # (block, n)
    else:
        from ..kernels.r1_sketch import sketch_gemv, sketch_gemv_t
        interp = mode == "pallas_interpret"
        p = sketch_gemv(r32, s, interpret=interp)
        for _ in range(it):
            p, _ = jnp.linalg.qr(p)  # skinny QR stays in XLA (cheap)
            p = sketch_gemv(r32, sketch_gemv_t(r32, p, interpret=interp),
                            interpret=interp)
        q, _ = jnp.linalg.qr(p)
        b = sketch_gemv_t(r32, q, interpret=interp).T
    return q.astype(residual.dtype), b.astype(residual.dtype)


@partial(jax.jit, static_argnames=("rank", "block", "it", "backend"))
def sketch_lowrank_block(
    a: jax.Array, key: jax.Array, rank: int, block: int = 8, it: int = 2,
    backend: str = "xla",
) -> Tuple[jax.Array, jax.Array]:
    """Beyond-paper: block power iteration (randomized subspace iteration)
    peeling ``block`` directions per pass. GEMM-shaped for the MXU; QR keeps
    the block orthonormal. Produces (U (m,r), V (r,n)). A trailing partial
    block handles rank % block != 0."""
    block = min(block, rank) if rank else block
    n_full, rem = divmod(rank, block)
    keys = jax.random.split(key, n_full + 1)
    mode = resolve_backend(backend, a.shape)

    def body(residual, k):
        u, v = _block_step(residual, k, block, it, mode)
        residual = residual - (u @ v).astype(residual.dtype)
        return residual, (u, v)

    resid, (us, vs) = jax.lax.scan(body, a, keys[:n_full])
    u = jnp.transpose(us, (1, 0, 2)).reshape(a.shape[0], n_full * block)
    v = vs.reshape(n_full * block, a.shape[1])
    if rem:
        # Partial blocks narrower than the kernel lane width run via XLA.
        u_r, v_r = _block_step(resid, keys[n_full], rem, it, "xla")
        u = jnp.concatenate([u, u_r], axis=1)
        v = jnp.concatenate([v, v_r], axis=0)
    return u, v


@partial(jax.jit, static_argnames=("max_rank", "block", "it", "backend"))
def sketch_lowrank_block_masked(
    a: jax.Array, key: jax.Array, rank: jax.Array, max_rank: int,
    block: int = 8, it: int = 2, backend: str = "xla",
) -> Tuple[jax.Array, jax.Array]:
    """Blocked sketch into fixed (m, max_rank)/(max_rank, n) buffers with a
    *traced* effective ``rank``: U columns / V rows with index >= rank are
    zero, and the residual only has the first ``rank`` components removed.

    This makes the whole sketch shape-uniform across layers whose R1-FLR
    ranks differ, so the batched BLC can ``vmap`` it over a layer stack.
    """
    m, n = a.shape
    if max_rank <= 0:
        return jnp.zeros((m, 0), a.dtype), jnp.zeros((0, n), a.dtype)
    block = min(block, max_rank)
    n_steps = -(-max_rank // block)  # ceil
    keys = jax.random.split(key, n_steps)
    mode = resolve_backend(backend, a.shape)
    rank = jnp.asarray(rank, jnp.int32)

    u_buf = jnp.zeros((m, n_steps * block), a.dtype)
    v_buf = jnp.zeros((n_steps * block, n), a.dtype)

    def cond(state):
        # Stop at this layer's own rank — a while_loop (not a scan) so a
        # layer whose R1-FLR rank is far below max_rank does not pay for
        # max_rank worth of block sketches. Under vmap the loop runs until
        # the deepest-rank layer of the stack is done; finished layers are
        # masked no-ops.
        _, j, _, _ = state
        return j * block < rank

    def body(state):
        residual, j, u_buf, v_buf = state
        u, v = _block_step(residual, keys[j], block, it, mode)
        # Rotate the block onto its principal axes (small SVD of the
        # (block, n) factor; u @ v is unchanged) so that masking a partial
        # block keeps the *dominant* directions — raw QR columns are not
        # energy-ordered and truncating them drops arbitrary directions.
        ub, sv, vt = jnp.linalg.svd(v.astype(jnp.float32),
                                    full_matrices=False)
        u = (u.astype(jnp.float32) @ ub).astype(u.dtype)
        v = (sv[:, None] * vt).astype(v.dtype)
        col = j * block + jnp.arange(block)
        keep = (col < rank).astype(u.dtype)
        u = u * keep[None, :]
        v = v * keep[:, None]
        u_buf = jax.lax.dynamic_update_slice(u_buf, u, (0, j * block))
        v_buf = jax.lax.dynamic_update_slice(v_buf, v, (j * block, 0))
        residual = residual - (u @ v).astype(residual.dtype)
        return (residual, j + 1, u_buf, v_buf)

    _, _, u_buf, v_buf = jax.lax.while_loop(
        cond, body, (a, jnp.int32(0), u_buf, v_buf))
    return u_buf[:, :max_rank], v_buf[:max_rank, :]


def sketch_apply(u: jax.Array, v: jax.Array, x: jax.Array) -> jax.Array:
    """(U V) @ x computed low-rank-wise: U @ (V @ x)."""
    return u @ (v @ x)
