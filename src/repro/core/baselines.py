"""Baseline PTQ methods the paper compares against (Table 2 / Table 4).

All share the contract  ``method(w, x_calib, bits, key) -> (w_hat, info)``
where ``w_hat`` is the effective dequantized matrix, so quality benchmarks
can score every method with the same ``recon_error``.

  * RTN        — round-to-nearest group quant, no tricks.
  * AWQ-like   — activation scaling + clip search (no low-rank).
  * LQER-like  — fixed-rank SVD low-rank + RTN on residual (rank from cfg).
  * FLRQ       — via ``core.flrq`` (with/without BLC for the ablation).
  * GPTQ       — in ``core.gptq`` (OBS column-wise, its own API shape).
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from .quantize import (
    QuantSpec,
    awq_scale,
    channel_mean_abs,
    pseudo_quantize,
    search_clip_ratio,
)
from .rsvd import truncated_svd


def rtn(w, x_calib, bits, key=None, group_size=128, symmetric=False):
    spec = QuantSpec(bits, group_size, symmetric)
    return pseudo_quantize(w.astype(jnp.float32), spec), dict(rank=0)


def awq_like(w, x_calib, bits, key=None, group_size=128, symmetric=False):
    """Activation-aware scaling + clip search. Like the real AWQ, the
    scaling strength is grid-searched: alpha = mean|x|^s, s in [0, 1],
    keeping the s that minimizes output reconstruction error (s = 0 is
    plain RTN+clip, so this never regresses below it)."""
    spec = QuantSpec(bits, group_size, symmetric)
    w32 = w.astype(jnp.float32)
    n = w.shape[1]
    x32 = None
    if x_calib is not None and x_calib.shape[0] > 0:
        x32 = x_calib.astype(jnp.float32)
        xmean = channel_mean_abs(x32)
    best = None
    for s in (0.0, 0.25, 0.5, 0.75, 1.0):
        if x32 is None and s > 0:
            break
        if s == 0.0:
            alpha = jnp.ones((n,), jnp.float32)
        else:
            a = jnp.maximum(xmean, 1e-6) ** s
            alpha = jnp.clip(a / jnp.exp(jnp.mean(jnp.log(a))), 1e-2, 1e2)
        ws = w32 * alpha[None, :]
        xs = (x32 / alpha[None, :]).T if x32 is not None else None
        clip = search_clip_ratio(ws, xs, spec)
        what = pseudo_quantize(ws, spec, clip) / alpha[None, :]
        err = float(
            jnp.linalg.norm((w32 - what) @ (x32.T if x32 is not None else jnp.eye(n)))
        )
        if best is None or err < best[0]:
            best = (err, what, float(clip), s)
    _, what, clip, s = best
    return what, dict(rank=0, clip=clip, scale_exp=s)


def lqer_like(
    w, x_calib, bits, key=None, rank: int = 32, group_size=128, symmetric=False
):
    """LQER: quantize first, then fixed-rank SVD of the *quantization error*
    (W − Q(W)) kept in higher precision."""
    spec = QuantSpec(bits, group_size, symmetric)
    w32 = w.astype(jnp.float32)
    wq = pseudo_quantize(w32, spec)
    u, v = truncated_svd(w32 - wq, rank)
    return wq + u @ v, dict(rank=rank)


def fixed_rank_then_quant(
    w, x_calib, bits, key=None, rank: int = 32, group_size=128, symmetric=False
):
    """LoRC/SVD-Quant style: peel top-``rank`` SVD of W first, quantize the
    residual (the 'low-rank within quantization' family)."""
    spec = QuantSpec(bits, group_size, symmetric)
    w32 = w.astype(jnp.float32)
    u, v = truncated_svd(w32, rank)
    wq = pseudo_quantize(w32 - u @ v, spec)
    return wq + u @ v, dict(rank=rank)
