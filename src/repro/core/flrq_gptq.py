"""FLRQ ∘ GPTQ: composing the paper's low-rank decomposition with OBS
column-wise quantization.

The paper positions FLRQ as "easily integrated with other approaches"
(its released pipeline combines with RILQ/Quip#; Table 5). The natural
in-repo composition is with GPTQ: use R1-FLR to pick the flexible-rank
component first (absorbing outliers / dominant directions), then run the
Hessian-aware GPTQ pass on the residual W − W_r instead of plain RTN:

    W ≈ GPTQ(W − W_r; H) + W_r,     W_r = R1-FLR(αW) / α

and optionally one BLC-style refresh of W_r against the *GPTQ* residual.
GPTQ's error feedback handles intra-row rounding; the low-rank part
handles the cross-row structure GPTQ cannot represent — they compose
because they correct orthogonal error modes.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from .flr import flexible_rank_select_py
from .flrq import FLRQConfig, LayerStats
from .gptq import gptq_quantize
from .quantize import (
    awq_scale,
    channel_mean_abs,
    pseudo_quantize,
    recon_error,
)
from .r1_sketch import sketch_lowrank


def flrq_gptq_quantize(
    w: jax.Array,
    x_calib: Optional[jax.Array],
    cfg: FLRQConfig,
    key: jax.Array,
    refresh_lowrank: bool = True,
    name: str = "w",
) -> Tuple[jax.Array, LayerStats]:
    """Returns (w_hat effective matrix, stats). Same contract as the
    baselines so quality benchmarks can score it directly."""
    t0 = time.perf_counter()
    m, n = w.shape
    w32 = w.astype(jnp.float32)
    xt = (jnp.zeros((0, n), jnp.float32) if x_calib is None
          else x_calib.astype(jnp.float32))

    # activation scaling (Eq. 10-11) with the same robustness gate as FLRQ
    if cfg.use_scaling and xt.shape[0] > 0:
        alpha = awq_scale(channel_mean_abs(xt))
    else:
        alpha = jnp.ones((n,), jnp.float32)
    ws = w32 * alpha[None, :]
    xs = xt / alpha[None, :]

    err_rtn = float(recon_error(w32, pseudo_quantize(w32, cfg.spec()),
                                xt.T if xt.shape[0] else None))

    # (1) flexible low-rank first
    key, k1, k2 = jax.random.split(key, 3)
    u, v, rank, _ = flexible_rank_select_py(ws, k1, cfg.flr())
    w_r = u @ v if rank else jnp.zeros_like(ws)

    # (2) Hessian-aware quantization of the residual
    what_q, _ = gptq_quantize(ws - w_r, xs if xs.shape[0] else None,
                              cfg.bits, group_size=cfg.group_size,
                              symmetric=cfg.symmetric)

    # (3) optional low-rank refresh against the GPTQ residual (one BLC step)
    if refresh_lowrank and rank:
        u, v = sketch_lowrank(ws - what_q, k2, rank, it=cfg.it)
        w_r = u @ v
        what_q, _ = gptq_quantize(ws - w_r, xs if xs.shape[0] else None,
                                  cfg.bits, group_size=cfg.group_size,
                                  symmetric=cfg.symmetric)

    what = (what_q + w_r) / alpha[None, :]
    err = float(recon_error(w32, what, xt.T if xt.shape[0] else None))

    # robustness gate (as core.flrq): never regress below plain RTN
    if err > err_rtn and cfg.use_scaling:
        cfg2 = dataclasses.replace(cfg, use_scaling=False)
        return flrq_gptq_quantize(w, x_calib, cfg2, key,
                                  refresh_lowrank, name)

    stats = LayerStats(
        name=name, shape=(m, n), rank=int(rank), err_before=err_rtn,
        err_after=err,
        extra_bits=16.0 * rank * (m + n) / (m * n),
        clip=1.0, seconds=time.perf_counter() - t0,
    )
    return what, stats
