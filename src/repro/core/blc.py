"""BLC: Best Low-rank Approximation under Clipping (paper Alg. 2 core loop).

Alternating minimization of   E = ||W X − (W_r + W_q) X||₂   over the
low-rank factor W_r and the clipping ratio used when quantizing W − W_r:

    repeat `epochs` times:
      1. E      = ||W X − (W_r + W_q) X||
      2. R      = W − deq(W_q);      W_r ← sketch(R, rank)
      3. p'_clp = argmin_clip ||(W − W_r − Q(W−W_r; clip)) X||
         W_q   ← Quant(Clip(W − W_r, p'_clp))
      4. keep (W_r, W_q) of the best E seen

The rank is fixed to the R1-FLR selection made before BLC starts (re-running
flexible selection inside the loop would change the storage budget mid-
optimization; the paper's Alg. 2 likewise selects rank once, then iterates).

The epoch re-sketch uses the *blocked* R1-Sketch (block power iteration →
skinny GEMMs for the MXU) instead of peeling rank-1 components one scan
step at a time: same subspace semantics, ~block× fewer passes over the
residual. ``block=1`` recovers the paper-verbatim rank-1 peel.

The clip search (step 3) is the hottest loop of the whole quantizer and is
a ONE-PASS grid sweep here: per-group range stats are computed once per
epoch and every clip ratio is scored as a rescale of them. Backends
(``clip_backend``):
  * ``"xla"``    — hoisted jnp path: one ``group_stats`` reduction, then a
    lax.map over the grid that only pays the round-trip + objective GEMM
    (the seed recomputed the full reduction per grid point). A Frobenius
    objective (``x=None``) is scored as Σd² directly — never through the
    materialized eye(n) batch.
  * ``"pallas"`` — ``kernels.clip_sweep``: the whole grid's output errors
    from ONE ``pallas_call`` / one HBM read of W, then one re-quantization
    at the argmin via ``kernels.group_quant.group_pseudo_quant``. Off-TPU
    this runs in interpret mode (validation, not speed).
  * ``"auto"``   — pallas on TPU when the (bits, shape) fit the kernel,
    XLA everywhere else.

Two drivers:
  * ``blc``          — one (m, n) matrix; one lax.scan over epochs.
  * ``blc_batched``  — a whole (L, m, n) layer stack in ONE jitted program.
    Layer ranks differ (that is FLRQ's point), so the low-rank factors live
    in fixed (m, max_rank) buffers and each layer's sketch is masked to its
    own traced rank (``sketch_lowrank_block_masked``); the scan over epochs
    is vmapped over L.
"""
from __future__ import annotations

from functools import partial
from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp

from .quantize import (
    DEFAULT_CLIP_GRID,
    QuantSpec,
    clip_errors_from_stats,
    group_stats,
    pseudo_quantize_from_stats,
    recon_error,
)
from .r1_sketch import sketch_lowrank_block, sketch_lowrank_block_masked

CLIP_BACKENDS = ("xla", "pallas", "auto")


class BLCResult(NamedTuple):
    u: jax.Array            # (m, r) best low-rank left factor
    v: jax.Array            # (r, n) best right factor
    w_q: jax.Array          # (m, n) best dequantized quantized part
    clip: jax.Array         # best clip ratio (scalar)
    err: jax.Array          # best relative output error E
    err_trace: jax.Array    # (epochs + 1,) E per epoch (paper Fig. 13)


def resolve_clip_backend(backend: str, shape, bits: int,
                         group: int = 128) -> str:
    """Map a clip-backend choice to a concrete mode: "xla" | "pallas" |
    "pallas_interpret" (forced Pallas off-TPU). Mirrors
    ``r1_sketch.resolve_backend``: auto falls back to XLA off-TPU or when
    the (bits, shape, group) cannot tile the clip-path kernels; forced
    pallas raises on untileable configs."""
    if backend not in CLIP_BACKENDS:
        raise ValueError(f"clip_backend={backend!r} not in {CLIP_BACKENDS}")
    if backend == "xla":
        return "xla"
    from ..kernels.clip_sweep import kernel_shape_ok
    m, n = int(shape[0]), int(shape[1])
    if bits not in (2, 4, 8) or not kernel_shape_ok(m, n, group):
        if backend == "pallas":
            raise ValueError(
                f"clip_backend='pallas' but (bits={bits}, shape=({m}, {n}),"
                f" group={group}) does not fit the clip-sweep kernels; use "
                "'auto' for fallback")
        return "xla"
    on_tpu = jax.default_backend() == "tpu"
    if backend == "pallas":
        return "pallas" if on_tpu else "pallas_interpret"
    return "pallas" if on_tpu else "xla"  # auto


def _best_clip_quant(w_resid, x, spec: QuantSpec, grid, mode: str = "xla"):
    """Quantize w_resid under every clip ratio in ``grid`` (a static
    tuple), return (w_q, clip) minimizing output error against ``x``
    ((n, b) column batch, or None for the Frobenius objective).

    One-pass sweep: the per-group range reduction runs ONCE for the whole
    grid (each clip only rescales it), candidate matrices are scored and
    discarded, and the winner is re-quantized once — on the kernel path the
    entire grid's errors come from a single ``pallas_call`` over W."""
    garr = jnp.asarray(grid, jnp.float32)
    if mode == "xla":
        stats = group_stats(w_resid, spec)
        errs = clip_errors_from_stats(w_resid, x, spec, stats, garr)
        clip = garr[jnp.argmin(errs)]
        return pseudo_quantize_from_stats(w_resid, stats, spec, clip), clip

    from ..kernels.clip_sweep import clip_sweep_errors
    from ..kernels.group_quant import group_pseudo_quant
    interpret = mode == "pallas_interpret"
    errs = clip_sweep_errors(
        w_resid, x, clips=grid, bits=spec.bits, group=spec.group_size,
        symmetric=spec.symmetric, interpret=interpret)
    clip = garr[jnp.argmin(errs)]
    # bk matches the sweep's bn so kernel_shape_ok gates both launches
    wq = group_pseudo_quant(
        w_resid, clip, bits=spec.bits, group=spec.group_size,
        symmetric=spec.symmetric, bk=512, interpret=interpret)
    return wq.astype(w_resid.dtype), clip


@partial(jax.jit, static_argnames=("spec", "rank", "epochs", "it", "block",
                                   "backend", "clip_grid", "clip_backend"))
def blc(
    w: jax.Array,
    x: Optional[jax.Array],
    key: jax.Array,
    spec: QuantSpec,
    rank: int,
    epochs: int = 8,
    it: int = 2,
    block: int = 8,
    clip_grid=DEFAULT_CLIP_GRID,
    backend: str = "xla",
    clip_backend: str = "xla",
) -> BLCResult:
    """Run BLC. ``w``: (m, n) weight (already activation-scaled if scaling is
    on), ``x``: (n, b) calibration activations in the same scaled space, or
    None for the Frobenius objective (no-calib quantization — scored
    directly, never through a materialized eye(n) batch)."""
    x32 = None if x is None else x.astype(jnp.float32)
    grid = tuple(float(c) for c in clip_grid)
    clip_mode = resolve_clip_backend(clip_backend, w.shape, spec.bits,
                                     spec.group_size)
    keys = jax.random.split(key, epochs + 1)

    def sketch(r, k):
        return sketch_lowrank_block(r, k, rank, block=block, it=it,
                                    backend=backend)

    # --- initialization: W_r from W, then clipped quant of the residual ----
    if rank > 0:
        u0, v0 = sketch(w, keys[0])
    else:
        m, n = w.shape
        u0 = jnp.zeros((m, 0), w.dtype)
        v0 = jnp.zeros((0, n), w.dtype)
    wq0, clip0 = _best_clip_quant(w - u0 @ v0, x32, spec, grid, clip_mode)
    err0 = recon_error(w, wq0 + u0 @ v0, x32)

    def epoch(carry, k):
        u, v, wq, clip, best = carry
        bu, bv, bwq, bclip, berr = best
        # (2) re-sketch the *quantization* residual
        r = w - wq
        if rank > 0:
            u, v = sketch(r, k)
        # (3) re-quantize under a fresh clip search
        wq, clip = _best_clip_quant(w - u @ v, x32, spec, grid, clip_mode)
        # (1)/(4) score and keep the best
        err = recon_error(w, wq + u @ v, x32)
        better = err < berr
        best = (
            jnp.where(better, u, bu),
            jnp.where(better, v, bv),
            jnp.where(better, wq, bwq),
            jnp.where(better, clip, bclip),
            jnp.minimum(err, berr),
        )
        return (u, v, wq, clip, best), err

    init = (u0, v0, wq0, clip0, (u0, v0, wq0, clip0, err0))
    (_, _, _, _, best), errs = jax.lax.scan(epoch, init, keys[1:])
    bu, bv, bwq, bclip, berr = best
    trace = jnp.concatenate([jnp.asarray([err0]), errs])
    return BLCResult(bu, bv, bwq, bclip, berr, trace)


@partial(jax.jit, static_argnames=("spec", "max_rank", "epochs", "it",
                                   "block", "backend", "clip_grid",
                                   "clip_backend"))
def blc_batched(
    w: jax.Array,
    x: Optional[jax.Array],
    keys: jax.Array,
    spec: QuantSpec,
    ranks: jax.Array,
    max_rank: int,
    epochs: int = 8,
    it: int = 2,
    block: int = 8,
    clip_grid=DEFAULT_CLIP_GRID,
    backend: str = "xla",
    clip_backend: str = "xla",
) -> BLCResult:
    """BLC for a whole (L, m, n) layer stack in ONE jitted program.

    ``x``: the calibration batch — (n, b) shared by every layer of the
    stack (the stacked tensors of one weight family see the same
    activations), (L, n, b) *per-layer* objectives (what the same-shape
    stack fusion produces when it concatenates weight families that see
    different activations into one launch), or None (Frobenius objective
    for every layer).
    ``keys``: (L, 2); ``ranks``: (L,) traced per-layer R1-FLR ranks;
    ``max_rank``: static buffer width >= max(ranks).

    Returns a BLCResult whose fields carry a leading L dim, with u/v padded
    to ``max_rank`` (columns/rows beyond each layer's rank are exactly
    zero, so downstream packing can slice to the realized max).
    """
    x32 = None if x is None else x.astype(jnp.float32)
    grid = tuple(float(c) for c in clip_grid)
    clip_mode = resolve_clip_backend(clip_backend, w.shape[1:], spec.bits,
                                     spec.group_size)
    ranks = jnp.asarray(ranks, jnp.int32)
    per_lane_x = x32 is not None and x32.ndim == 3

    def one_layer(w_l, x_l, key_l, rank_l):
        ks = jax.random.split(key_l, epochs + 1)

        def sketch(r, k):
            return sketch_lowrank_block_masked(
                r, k, rank_l, max_rank, block=block, it=it, backend=backend)

        u0, v0 = sketch(w_l, ks[0])
        wq0, clip0 = _best_clip_quant(w_l - u0 @ v0, x_l, spec, grid,
                                      clip_mode)
        err0 = recon_error(w_l, wq0 + u0 @ v0, x_l)

        def epoch(carry, k):
            u, v, wq, clip, best = carry
            bu, bv, bwq, bclip, berr = best
            u, v = sketch(w_l - wq, k)
            wq, clip = _best_clip_quant(w_l - u @ v, x_l, spec, grid,
                                        clip_mode)
            err = recon_error(w_l, wq + u @ v, x_l)
            better = err < berr
            best = (
                jnp.where(better, u, bu),
                jnp.where(better, v, bv),
                jnp.where(better, wq, bwq),
                jnp.where(better, clip, bclip),
                jnp.minimum(err, berr),
            )
            return (u, v, wq, clip, best), err

        init = (u0, v0, wq0, clip0, (u0, v0, wq0, clip0, err0))
        (_, _, _, _, best), errs = jax.lax.scan(epoch, init, ks[1:])
        bu, bv, bwq, bclip, berr = best
        trace = jnp.concatenate([jnp.asarray([err0]), errs])
        return BLCResult(bu, bv, bwq, bclip, berr, trace)

    if x32 is None:
        return jax.vmap(
            lambda w_l, key_l, rank_l: one_layer(w_l, None, key_l, rank_l)
        )(w, keys, ranks)
    return jax.vmap(one_layer, in_axes=(0, 0 if per_lane_x else None, 0, 0)
                    )(w, x32, keys, ranks)
