"""BLC: Best Low-rank Approximation under Clipping (paper Alg. 2 core loop).

Alternating minimization of   E = ||W X − (W_r + W_q) X||₂   over the
low-rank factor W_r and the clipping ratio used when quantizing W − W_r:

    repeat `epochs` times:
      1. E      = ||W X − (W_r + W_q) X||
      2. R      = W − deq(W_q);      W_r ← sketch(R, rank)
      3. p'_clp = argmin_clip ||(W − W_r − Q(W−W_r; clip)) X||
         W_q   ← Quant(Clip(W − W_r, p'_clp))
      4. keep (W_r, W_q) of the best E seen

The rank is fixed to the R1-FLR selection made before BLC starts (re-running
flexible selection inside the loop would change the storage budget mid-
optimization; the paper's Alg. 2 likewise selects rank once, then iterates).

Fully jittable: one ``lax.scan`` over epochs; each epoch re-sketches the
quantization residual with the R1-Sketch peel.
"""
from __future__ import annotations

from functools import partial
from typing import NamedTuple

import jax
import jax.numpy as jnp

from .quantize import QuantSpec, pseudo_quantize, recon_error
from .r1_sketch import sketch_lowrank


class BLCResult(NamedTuple):
    u: jax.Array            # (m, r) best low-rank left factor
    v: jax.Array            # (r, n) best right factor
    w_q: jax.Array          # (m, n) best dequantized quantized part
    clip: jax.Array         # best clip ratio (scalar)
    err: jax.Array          # best relative output error E
    err_trace: jax.Array    # (epochs + 1,) E per epoch (paper Fig. 13)


def _best_clip_quant(w_resid, x, spec: QuantSpec, grid):
    """Quantize w_resid under every clip ratio in grid, return (w_q, clip)
    minimizing output error against x."""

    def one(c):
        wq = pseudo_quantize(w_resid, spec, c)
        d = (w_resid - wq).astype(jnp.float32)
        dx = d @ x
        return wq, jnp.sum(dx * dx)

    wqs, errs = jax.lax.map(one, grid)
    i = jnp.argmin(errs)
    return wqs[i], grid[i]


@partial(jax.jit, static_argnames=("spec", "rank", "epochs", "it"))
def blc(
    w: jax.Array,
    x: jax.Array,
    key: jax.Array,
    spec: QuantSpec,
    rank: int,
    epochs: int = 8,
    it: int = 2,
    clip_grid=(1.0, 0.95, 0.9, 0.85, 0.8, 0.75, 0.7, 0.65),
) -> BLCResult:
    """Run BLC. ``w``: (m, n) weight (already activation-scaled if scaling is
    on), ``x``: (n, b) calibration activations in the same scaled space."""
    x32 = x.astype(jnp.float32)
    grid = jnp.asarray(clip_grid, jnp.float32)
    keys = jax.random.split(key, epochs + 1)

    # --- initialization: W_r from W, then clipped quant of the residual ----
    if rank > 0:
        u0, v0 = sketch_lowrank(w, keys[0], rank, it=it)
    else:
        m, n = w.shape
        u0 = jnp.zeros((m, 0), w.dtype)
        v0 = jnp.zeros((0, n), w.dtype)
    wq0, clip0 = _best_clip_quant(w - u0 @ v0, x32, spec, grid)
    err0 = recon_error(w, wq0 + u0 @ v0, x32)

    def epoch(carry, k):
        u, v, wq, clip, best = carry
        bu, bv, bwq, bclip, berr = best
        # (2) re-sketch the *quantization* residual
        r = w - wq
        if rank > 0:
            u, v = sketch_lowrank(r, k, rank, it=it)
        # (3) re-quantize under a fresh clip search
        wq, clip = _best_clip_quant(w - u @ v, x32, spec, grid)
        # (1)/(4) score and keep the best
        err = recon_error(w, wq + u @ v, x32)
        better = err < berr
        best = (
            jnp.where(better, u, bu),
            jnp.where(better, v, bv),
            jnp.where(better, wq, bwq),
            jnp.where(better, clip, bclip),
            jnp.minimum(err, berr),
        )
        return (u, v, wq, clip, best), err

    init = (u0, v0, wq0, clip0, (u0, v0, wq0, clip0, err0))
    (_, _, _, _, best), errs = jax.lax.scan(epoch, init, keys[1:])
    bu, bv, bwq, bclip, berr = best
    trace = jnp.concatenate([jnp.asarray([err0]), errs])
    return BLCResult(bu, bv, bwq, bclip, berr, trace)
