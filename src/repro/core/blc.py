"""BLC: Best Low-rank Approximation under Clipping (paper Alg. 2 core loop).

Alternating minimization of   E = ||W X − (W_r + W_q) X||₂   over the
low-rank factor W_r and the clipping ratio used when quantizing W − W_r:

    repeat `epochs` times:
      1. E      = ||W X − (W_r + W_q) X||
      2. R      = W − deq(W_q);      W_r ← sketch(R, rank)
      3. p'_clp = argmin_clip ||(W − W_r − Q(W−W_r; clip)) X||
         W_q   ← Quant(Clip(W − W_r, p'_clp))
      4. keep (W_r, W_q) of the best E seen

The rank is fixed to the R1-FLR selection made before BLC starts (re-running
flexible selection inside the loop would change the storage budget mid-
optimization; the paper's Alg. 2 likewise selects rank once, then iterates).

The epoch re-sketch uses the *blocked* R1-Sketch (block power iteration →
skinny GEMMs for the MXU) instead of peeling rank-1 components one scan
step at a time: same subspace semantics, ~block× fewer passes over the
residual. ``block=1`` recovers the paper-verbatim rank-1 peel.

Two drivers:
  * ``blc``          — one (m, n) matrix; one lax.scan over epochs.
  * ``blc_batched``  — a whole (L, m, n) layer stack in ONE jitted program.
    Layer ranks differ (that is FLRQ's point), so the low-rank factors live
    in fixed (m, max_rank) buffers and each layer's sketch is masked to its
    own traced rank (``sketch_lowrank_block_masked``); the scan over epochs
    is vmapped over L.
"""
from __future__ import annotations

from functools import partial
from typing import NamedTuple

import jax
import jax.numpy as jnp

from .quantize import DEFAULT_CLIP_GRID, QuantSpec, pseudo_quantize, recon_error
from .r1_sketch import sketch_lowrank_block, sketch_lowrank_block_masked


class BLCResult(NamedTuple):
    u: jax.Array            # (m, r) best low-rank left factor
    v: jax.Array            # (r, n) best right factor
    w_q: jax.Array          # (m, n) best dequantized quantized part
    clip: jax.Array         # best clip ratio (scalar)
    err: jax.Array          # best relative output error E
    err_trace: jax.Array    # (epochs + 1,) E per epoch (paper Fig. 13)


def _best_clip_quant(w_resid, x, spec: QuantSpec, grid):
    """Quantize w_resid under every clip ratio in grid, return (w_q, clip)
    minimizing output error against x. Scores all clips first (discarding
    the candidate matrices) and re-quantizes once at the winner — one extra
    quant pass instead of materializing a (grid, m, n) stack."""

    def one(c):
        wq = pseudo_quantize(w_resid, spec, c)
        d = (w_resid - wq).astype(jnp.float32)
        dx = d @ x
        return jnp.sum(dx * dx)

    errs = jax.lax.map(one, grid)
    clip = grid[jnp.argmin(errs)]
    return pseudo_quantize(w_resid, spec, clip), clip


@partial(jax.jit, static_argnames=("spec", "rank", "epochs", "it", "block",
                                   "backend"))
def blc(
    w: jax.Array,
    x: jax.Array,
    key: jax.Array,
    spec: QuantSpec,
    rank: int,
    epochs: int = 8,
    it: int = 2,
    block: int = 8,
    clip_grid=DEFAULT_CLIP_GRID,
    backend: str = "xla",
) -> BLCResult:
    """Run BLC. ``w``: (m, n) weight (already activation-scaled if scaling is
    on), ``x``: (n, b) calibration activations in the same scaled space."""
    x32 = x.astype(jnp.float32)
    grid = jnp.asarray(clip_grid, jnp.float32)
    keys = jax.random.split(key, epochs + 1)

    def sketch(r, k):
        return sketch_lowrank_block(r, k, rank, block=block, it=it,
                                    backend=backend)

    # --- initialization: W_r from W, then clipped quant of the residual ----
    if rank > 0:
        u0, v0 = sketch(w, keys[0])
    else:
        m, n = w.shape
        u0 = jnp.zeros((m, 0), w.dtype)
        v0 = jnp.zeros((0, n), w.dtype)
    wq0, clip0 = _best_clip_quant(w - u0 @ v0, x32, spec, grid)
    err0 = recon_error(w, wq0 + u0 @ v0, x32)

    def epoch(carry, k):
        u, v, wq, clip, best = carry
        bu, bv, bwq, bclip, berr = best
        # (2) re-sketch the *quantization* residual
        r = w - wq
        if rank > 0:
            u, v = sketch(r, k)
        # (3) re-quantize under a fresh clip search
        wq, clip = _best_clip_quant(w - u @ v, x32, spec, grid)
        # (1)/(4) score and keep the best
        err = recon_error(w, wq + u @ v, x32)
        better = err < berr
        best = (
            jnp.where(better, u, bu),
            jnp.where(better, v, bv),
            jnp.where(better, wq, bwq),
            jnp.where(better, clip, bclip),
            jnp.minimum(err, berr),
        )
        return (u, v, wq, clip, best), err

    init = (u0, v0, wq0, clip0, (u0, v0, wq0, clip0, err0))
    (_, _, _, _, best), errs = jax.lax.scan(epoch, init, keys[1:])
    bu, bv, bwq, bclip, berr = best
    trace = jnp.concatenate([jnp.asarray([err0]), errs])
    return BLCResult(bu, bv, bwq, bclip, berr, trace)


@partial(jax.jit, static_argnames=("spec", "max_rank", "epochs", "it",
                                   "block", "backend"))
def blc_batched(
    w: jax.Array,
    x: jax.Array,
    keys: jax.Array,
    spec: QuantSpec,
    ranks: jax.Array,
    max_rank: int,
    epochs: int = 8,
    it: int = 2,
    block: int = 8,
    clip_grid=DEFAULT_CLIP_GRID,
    backend: str = "xla",
) -> BLCResult:
    """BLC for a whole (L, m, n) layer stack in ONE jitted program.

    ``x``: the calibration batch — (n, b) shared by every layer of the
    stack (the stacked tensors of one weight family see the same
    activations), or (L, n, b) *per-layer* objectives (what the same-shape
    stack fusion produces when it concatenates weight families that see
    different activations into one launch).
    ``keys``: (L, 2); ``ranks``: (L,) traced per-layer R1-FLR ranks;
    ``max_rank``: static buffer width >= max(ranks).

    Returns a BLCResult whose fields carry a leading L dim, with u/v padded
    to ``max_rank`` (columns/rows beyond each layer's rank are exactly
    zero, so downstream packing can slice to the realized max).
    """
    x32 = x.astype(jnp.float32)
    grid = jnp.asarray(clip_grid, jnp.float32)
    ranks = jnp.asarray(ranks, jnp.int32)
    per_lane_x = x32.ndim == 3

    def one_layer(w_l, x_l, key_l, rank_l):
        ks = jax.random.split(key_l, epochs + 1)

        def sketch(r, k):
            return sketch_lowrank_block_masked(
                r, k, rank_l, max_rank, block=block, it=it, backend=backend)

        u0, v0 = sketch(w_l, ks[0])
        wq0, clip0 = _best_clip_quant(w_l - u0 @ v0, x_l, spec, grid)
        err0 = recon_error(w_l, wq0 + u0 @ v0, x_l)

        def epoch(carry, k):
            u, v, wq, clip, best = carry
            bu, bv, bwq, bclip, berr = best
            u, v = sketch(w_l - wq, k)
            wq, clip = _best_clip_quant(w_l - u @ v, x_l, spec, grid)
            err = recon_error(w_l, wq + u @ v, x_l)
            better = err < berr
            best = (
                jnp.where(better, u, bu),
                jnp.where(better, v, bv),
                jnp.where(better, wq, bwq),
                jnp.where(better, clip, bclip),
                jnp.minimum(err, berr),
            )
            return (u, v, wq, clip, best), err

        init = (u0, v0, wq0, clip0, (u0, v0, wq0, clip0, err0))
        (_, _, _, _, best), errs = jax.lax.scan(epoch, init, ks[1:])
        bu, bv, bwq, bclip, berr = best
        trace = jnp.concatenate([jnp.asarray([err0]), errs])
        return BLCResult(bu, bv, bwq, bclip, berr, trace)

    return jax.vmap(one_layer, in_axes=(0, 0 if per_lane_x else None, 0, 0)
                    )(w, x32, keys, ranks)
