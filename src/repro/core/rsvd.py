"""Baseline low-rank approximations: exact/truncated SVD and RSVD.

These are the methods FLRQ's R1-Sketch replaces (paper Table 12, Fig 6).
Same (U, V) contract as ``r1_sketch.sketch_lowrank``: A ≈ U @ V.
"""
from __future__ import annotations

from functools import partial
from typing import Tuple

import jax
import jax.numpy as jnp


@partial(jax.jit, static_argnames=("rank",))
def truncated_svd(a: jax.Array, rank: int) -> Tuple[jax.Array, jax.Array]:
    """Exact top-``rank`` SVD (LAPACK on CPU; the paper's torch.linalg.svd
    analogue)."""
    u, s, vt = jnp.linalg.svd(a.astype(jnp.float32), full_matrices=False)
    ur = (u[:, :rank] * s[:rank]).astype(a.dtype)
    vr = vt[:rank, :].astype(a.dtype)
    return ur, vr


@partial(jax.jit, static_argnames=("rank", "it", "oversample"))
def rsvd(
    a: jax.Array, key: jax.Array, rank: int, it: int = 2, oversample: int = 8
) -> Tuple[jax.Array, jax.Array]:
    """Randomized SVD (Halko-Martinsson-Tropp), the algorithm R1-Sketch is a
    rank-1 specialization of. Stage A: Y = (AA*)^it A S, Q = qr(Y).
    Stage B: B = Q*A, svd(B)."""
    a32 = a.astype(jnp.float32)
    m, n = a.shape
    r = min(rank + oversample, min(m, n))
    s = jax.random.normal(key, (n, r), jnp.float32)
    y = a32 @ s
    for _ in range(it):
        q, _ = jnp.linalg.qr(y)
        y = a32 @ (a32.T @ q)
    q, _ = jnp.linalg.qr(y)  # (m, r)
    b = q.T @ a32  # (r, n)
    ub, sb, vtb = jnp.linalg.svd(b, full_matrices=False)
    u = (q @ ub[:, :rank]) * sb[:rank]
    return u.astype(a.dtype), vtb[:rank, :].astype(a.dtype)


def lowrank_error(a: jax.Array, u: jax.Array, v: jax.Array) -> jax.Array:
    """Relative Frobenius error of the rank-r approximation."""
    a32 = a.astype(jnp.float32)
    num = jnp.linalg.norm(a32 - (u.astype(jnp.float32) @ v.astype(jnp.float32)))
    return num / jnp.maximum(jnp.linalg.norm(a32), 1e-12)
