#!/usr/bin/env python
"""Self-contained lint gate (stdlib only — runs identically on a laptop
and in CI; no pinned third-party linter to drift against).

Checks every tracked .py file for:
  * syntax errors (compile())
  * tabs in indentation, trailing whitespace, CR/LF line endings
  * lines over 120 characters
  * leftover debugger hooks (breakpoint / pdb.set_trace calls)
  * merge-conflict markers

    python ci/lint.py [paths...]     # default: the whole repo

Exit codes: 0 clean, 1 findings.
"""
from __future__ import annotations

import os
import re
import sys

MAX_LINE = 120
DEBUGGER = re.compile(r"(?<!\w)(breakpoint\(\)|pdb\.set_trace\(\))")
CONFLICT = re.compile(r"^(<{7} |={7}$|>{7} )")
SKIP_DIRS = {".git", "__pycache__", ".pytest_cache", ".cache", "node_modules",
             ".hypothesis"}


def py_files(roots):
    for root in roots:
        if os.path.isfile(root):
            yield root
            continue
        for dirpath, dirnames, filenames in os.walk(root):
            dirnames[:] = [d for d in dirnames if d not in SKIP_DIRS]
            for f in filenames:
                if f.endswith(".py"):
                    yield os.path.join(dirpath, f)


def lint_file(path) -> list:
    findings = []
    with open(path, "rb") as f:
        raw = f.read()
    if b"\r" in raw:
        findings.append((path, 0, "CR/LF line endings"))
    text = raw.decode("utf-8", errors="replace")
    try:
        compile(text, path, "exec")
    except SyntaxError as e:
        findings.append((path, e.lineno or 0, f"syntax error: {e.msg}"))
        return findings
    for i, line in enumerate(text.splitlines(), 1):
        indent = line[: len(line) - len(line.lstrip())]
        if "\t" in indent:
            findings.append((path, i, "tab in indentation"))
        if line != line.rstrip():
            findings.append((path, i, "trailing whitespace"))
        if len(line) > MAX_LINE:
            findings.append((path, i, f"line too long ({len(line)} > {MAX_LINE})"))
        if DEBUGGER.search(line):
            findings.append((path, i, "debugger hook left in"))
        if CONFLICT.match(line):
            findings.append((path, i, "merge-conflict marker"))
    return findings


def main(argv=None) -> int:
    roots = (argv or sys.argv[1:]) or ["."]
    findings = []
    n = 0
    for path in sorted(set(py_files(roots))):
        n += 1
        findings.extend(lint_file(path))
    for path, line, msg in findings:
        print(f"{path}:{line}: {msg}")
    status = "clean" if not findings else f"{len(findings)} finding(s)"
    print(f"[lint] {n} files checked: {status}")
    return 1 if findings else 0


if __name__ == "__main__":
    sys.exit(main())
